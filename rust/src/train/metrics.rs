//! Run metrics: in-memory curves + CSV persistence + per-segment
//! update norms.
//!
//! Every experiment consumes [`RunLog`] rows keyed by *three* x-axes —
//! computation rounds (local steps), communication rounds, and simulated
//! wall-clock — because the paper plots Figure 1 against communication
//! rounds and Figure 2 against computation rounds for the same runs.
//!
//! [`segment_norms`] resolves a round's global update along the
//! backend's [`ParamLayout`]: per named segment, the L2 and L∞ norms of
//! the difference. This is what makes comm-savings tables show *where*
//! the bits go — parameter blocks with very different diff magnitudes
//! are exactly the case the per-tensor `q8pt` wire format exists for.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::ParamLayout;

/// Norms of one layout segment of an update/difference vector.
#[derive(Clone, Debug, PartialEq)]
pub struct SegmentNorm {
    /// Segment name from the layout (e.g. `block0.attn.wq`, `wte`).
    pub name: String,
    /// Coordinates in the segment.
    pub numel: usize,
    /// L2 norm of the segment's difference.
    pub l2: f64,
    /// L∞ (max |·|) norm of the segment's difference.
    pub linf: f64,
}

/// Per-segment norms of the elementwise difference `a - b`, resolved
/// along `layout` (both vectors must have `layout.param_count()`
/// coordinates). Accumulation is f64 in coordinate order.
pub fn segment_norms(layout: &ParamLayout, a: &[f32], b: &[f32]) -> Vec<SegmentNorm> {
    assert_eq!(a.len(), b.len(), "segment_norms: {} vs {} coordinates", a.len(), b.len());
    assert_eq!(
        a.len(),
        layout.param_count(),
        "segment_norms: {} coordinates vs a layout tiling {}",
        a.len(),
        layout.param_count()
    );
    layout
        .iter()
        .map(|e| {
            let r = e.offset..e.offset + e.numel();
            let mut sq = 0.0f64;
            let mut linf = 0.0f64;
            for (&x, &y) in a[r.clone()].iter().zip(&b[r]) {
                let d = (x - y) as f64;
                sq += d * d;
                linf = linf.max(d.abs());
            }
            SegmentNorm { name: e.name.clone(), numel: e.numel(), l2: sq.sqrt(), linf }
        })
        .collect()
}

/// Fixed-width table of per-segment norms — the "where the bits go"
/// block the experiments and examples print next to comm tables.
pub fn render_segment_norms(norms: &[SegmentNorm]) -> String {
    let name_w = norms.iter().map(|n| n.name.len()).max().unwrap_or(7).max("segment".len());
    let mut out =
        format!("{:<name_w$}  {:>10}  {:>12}  {:>12}\n", "segment", "numel", "l2", "linf");
    out.push_str(&"-".repeat(name_w + 2 + 10 + 2 + 12 + 2 + 12));
    out.push('\n');
    for n in norms {
        out.push_str(&format!(
            "{:<name_w$}  {:>10}  {:>12.4e}  {:>12.4e}\n",
            n.name, n.numel, n.l2, n.linf
        ));
    }
    out
}

#[derive(Clone, Debug, PartialEq)]
pub struct LogRow {
    /// Outer round index t.
    pub round: u64,
    /// Cumulative local (computation) steps per worker: t·τ.
    pub local_steps: u64,
    /// Cumulative communication rounds.
    pub comm_rounds: u64,
    /// Simulated wall-clock (measured compute + modeled comm), seconds.
    pub sim_time_s: f64,
    /// Mean training loss across workers since the previous row.
    pub train_loss: f64,
    /// Validation loss (NaN when this row did not evaluate).
    pub val_loss: f64,
    /// Local learning rate in effect.
    pub lr: f32,
}

#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub tag: String,
    pub rows: Vec<LogRow>,
}

impl RunLog {
    pub fn new(tag: &str) -> RunLog {
        RunLog { tag: tag.to_string(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: LogRow) {
        self.rows.push(row);
    }

    /// Last non-NaN validation loss.
    pub fn final_val_loss(&self) -> Option<f64> {
        self.rows.iter().rev().find(|r| !r.val_loss.is_nan()).map(|r| r.val_loss)
    }

    /// Best (minimum) validation loss over the run.
    pub fn best_val_loss(&self) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| !r.val_loss.is_nan())
            .map(|r| r.val_loss)
            .min_by(|a, b| a.total_cmp(b)) // identical order: NaN rows are filtered out
    }

    /// (x, val_loss) curve against the chosen axis.
    pub fn val_curve(&self, axis: Axis) -> Vec<(f64, f64)> {
        self.rows
            .iter()
            .filter(|r| !r.val_loss.is_nan())
            .map(|r| (axis.of(r), r.val_loss))
            .collect()
    }

    pub fn train_curve(&self, axis: Axis) -> Vec<(f64, f64)> {
        self.rows
            .iter()
            .filter(|r| !r.train_loss.is_nan())
            .map(|r| (axis.of(r), r.train_loss))
            .collect()
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("{path:?}"))?;
        writeln!(f, "round,local_steps,comm_rounds,sim_time_s,train_loss,val_loss,lr")?;
        for r in &self.rows {
            writeln!(
                f,
                "{},{},{},{:.6},{:.6},{:.6},{:.6e}",
                r.round, r.local_steps, r.comm_rounds, r.sim_time_s, r.train_loss, r.val_loss, r.lr
            )?;
        }
        Ok(())
    }

    pub fn read_csv(path: &Path) -> Result<RunLog> {
        let text = std::fs::read_to_string(path).with_context(|| format!("{path:?}"))?;
        let mut rows = Vec::new();
        for line in text.lines().skip(1) {
            let p: Vec<&str> = line.split(',').collect();
            if p.len() != 7 {
                continue;
            }
            rows.push(LogRow {
                round: p[0].parse()?,
                local_steps: p[1].parse()?,
                comm_rounds: p[2].parse()?,
                sim_time_s: p[3].parse()?,
                train_loss: p[4].parse()?,
                val_loss: p[5].parse()?,
                lr: p[6].parse()?,
            });
        }
        Ok(RunLog {
            tag: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
            rows,
        })
    }
}

#[derive(Clone, Copy, Debug)]
pub enum Axis {
    CommRounds,
    LocalSteps,
    SimTime,
}

impl Axis {
    fn of(&self, r: &LogRow) -> f64 {
        match self {
            Axis::CommRounds => r.comm_rounds as f64,
            Axis::LocalSteps => r.local_steps as f64,
            Axis::SimTime => r.sim_time_s,
        }
    }
}

/// Render a compact ASCII chart of (x, y) curves — the harness's stand-in
/// for the paper's matplotlib figures.
pub fn ascii_chart(
    title: &str,
    curves: &[(&str, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
) -> String {
    let mut all: Vec<(f64, f64)> = curves.iter().flat_map(|(_, c)| c.iter().copied()).collect();
    all.retain(|(x, y)| x.is_finite() && y.is_finite());
    if all.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (xmin, xmax) =
        all.iter().fold((f64::MAX, f64::MIN), |(lo, hi), &(x, _)| (lo.min(x), hi.max(x)));
    let (ymin, ymax) =
        all.iter().fold((f64::MAX, f64::MIN), |(lo, hi), &(_, y)| (lo.min(y), hi.max(y)));
    let yspan = (ymax - ymin).max(1e-12);
    let xspan = (xmax - xmin).max(1e-12);
    let mut grid = vec![vec![b' '; width]; height];
    let marks = [b'*', b'+', b'o', b'x', b'#', b'@'];
    for (ci, (_, curve)) in curves.iter().enumerate() {
        for &(x, y) in curve {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let col = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let row = height - 1 - (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = marks[ci % marks.len()];
        }
    }
    let mut out = format!("{title}\n");
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:8.3} |")
        } else if i == height - 1 {
            format!("{ymin:8.3} |")
        } else {
            "         |".to_string()
        };
        out.push_str(&label);
        out.push_str(&String::from_utf8_lossy(row)); // plot rows are ASCII marks
        out.push('\n');
    }
    out.push_str(&format!("          +{}\n", "-".repeat(width)));
    out.push_str(&format!("           x: {xmin:.1} .. {xmax:.1}   "));
    for (ci, (name, _)) in curves.iter().enumerate() {
        out.push_str(&format!("[{}] {}  ", marks[ci % marks.len()] as char, name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(round: u64, val: f64) -> LogRow {
        LogRow {
            round,
            local_steps: round * 12,
            comm_rounds: round,
            sim_time_s: round as f64 * 0.5,
            train_loss: 5.0 - round as f64 * 0.1,
            val_loss: val,
            lr: 1e-3,
        }
    }

    #[test]
    fn final_and_best_val() {
        let mut log = RunLog::new("t");
        log.push(row(1, 4.0));
        log.push(row(2, 3.5));
        log.push(row(3, f64::NAN));
        log.push(row(4, 3.7));
        assert_eq!(log.final_val_loss(), Some(3.7));
        assert_eq!(log.best_val_loss(), Some(3.5));
    }

    #[test]
    fn csv_roundtrip() {
        let mut log = RunLog::new("rt");
        log.push(row(1, 4.0));
        log.push(row(2, f64::NAN));
        let dir = std::env::temp_dir().join("dsm_test_metrics");
        let path = dir.join("rt.csv");
        log.write_csv(&path).unwrap();
        let back = RunLog::read_csv(&path).unwrap();
        assert_eq!(back.rows.len(), 2);
        assert_eq!(back.rows[0].round, 1);
        assert!((back.rows[0].val_loss - 4.0).abs() < 1e-9);
        assert!(back.rows[1].val_loss.is_nan());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn curves_respect_axis() {
        let mut log = RunLog::new("ax");
        log.push(row(2, 4.0));
        let c = log.val_curve(Axis::LocalSteps);
        assert_eq!(c, vec![(24.0, 4.0)]);
        let c = log.val_curve(Axis::CommRounds);
        assert_eq!(c, vec![(2.0, 4.0)]);
    }

    #[test]
    fn segment_norms_resolve_the_layout() {
        use crate::runtime::ParamEntry;
        let layout = ParamLayout::from_entries(
            vec![
                ParamEntry { name: "small".into(), offset: 0, shape: vec![2] },
                ParamEntry { name: "big".into(), offset: 2, shape: vec![2] },
            ],
            4,
        )
        .unwrap();
        let a = vec![1.0f32, 1.0, 1.0, 1.0];
        let b = vec![1.001f32, 0.999, 4.0, -2.0];
        let norms = segment_norms(&layout, &a, &b);
        assert_eq!(norms.len(), 2);
        assert_eq!(norms[0].name, "small");
        assert_eq!(norms[0].numel, 2);
        assert!((norms[0].linf - 1e-3).abs() < 1e-6, "{}", norms[0].linf);
        assert_eq!(norms[1].linf, 3.0);
        let expect_l2 = (9.0f64 + 9.0).sqrt();
        assert!((norms[1].l2 - expect_l2).abs() < 1e-9);
        // hetero magnitudes across segments is exactly what the table
        // is for: the rendered block carries both rows
        let table = render_segment_norms(&norms);
        assert!(table.contains("small") && table.contains("big"));
        assert!(table.contains("segment"));
    }

    #[test]
    fn ascii_chart_renders_all_series() {
        let a = vec![(0.0, 1.0), (1.0, 0.5), (2.0, 0.2)];
        let b = vec![(0.0, 1.0), (1.0, 0.8), (2.0, 0.6)];
        let s = ascii_chart("demo", &[("fast", a), ("slow", b)], 30, 8);
        assert!(s.contains('*') && s.contains('+'));
        assert!(s.contains("fast") && s.contains("slow"));
    }
}
