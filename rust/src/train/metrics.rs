//! Run metrics: in-memory curves + CSV persistence.
//!
//! Every experiment consumes [`RunLog`] rows keyed by *three* x-axes —
//! computation rounds (local steps), communication rounds, and simulated
//! wall-clock — because the paper plots Figure 1 against communication
//! rounds and Figure 2 against computation rounds for the same runs.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct LogRow {
    /// Outer round index t.
    pub round: u64,
    /// Cumulative local (computation) steps per worker: t·τ.
    pub local_steps: u64,
    /// Cumulative communication rounds.
    pub comm_rounds: u64,
    /// Simulated wall-clock (measured compute + modeled comm), seconds.
    pub sim_time_s: f64,
    /// Mean training loss across workers since the previous row.
    pub train_loss: f64,
    /// Validation loss (NaN when this row did not evaluate).
    pub val_loss: f64,
    /// Local learning rate in effect.
    pub lr: f32,
}

#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub tag: String,
    pub rows: Vec<LogRow>,
}

impl RunLog {
    pub fn new(tag: &str) -> RunLog {
        RunLog { tag: tag.to_string(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: LogRow) {
        self.rows.push(row);
    }

    /// Last non-NaN validation loss.
    pub fn final_val_loss(&self) -> Option<f64> {
        self.rows.iter().rev().find(|r| !r.val_loss.is_nan()).map(|r| r.val_loss)
    }

    /// Best (minimum) validation loss over the run.
    pub fn best_val_loss(&self) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| !r.val_loss.is_nan())
            .map(|r| r.val_loss)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// (x, val_loss) curve against the chosen axis.
    pub fn val_curve(&self, axis: Axis) -> Vec<(f64, f64)> {
        self.rows
            .iter()
            .filter(|r| !r.val_loss.is_nan())
            .map(|r| (axis.of(r), r.val_loss))
            .collect()
    }

    pub fn train_curve(&self, axis: Axis) -> Vec<(f64, f64)> {
        self.rows
            .iter()
            .filter(|r| !r.train_loss.is_nan())
            .map(|r| (axis.of(r), r.train_loss))
            .collect()
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("{path:?}"))?;
        writeln!(f, "round,local_steps,comm_rounds,sim_time_s,train_loss,val_loss,lr")?;
        for r in &self.rows {
            writeln!(
                f,
                "{},{},{},{:.6},{:.6},{:.6},{:.6e}",
                r.round, r.local_steps, r.comm_rounds, r.sim_time_s, r.train_loss, r.val_loss, r.lr
            )?;
        }
        Ok(())
    }

    pub fn read_csv(path: &Path) -> Result<RunLog> {
        let text = std::fs::read_to_string(path).with_context(|| format!("{path:?}"))?;
        let mut rows = Vec::new();
        for line in text.lines().skip(1) {
            let p: Vec<&str> = line.split(',').collect();
            if p.len() != 7 {
                continue;
            }
            rows.push(LogRow {
                round: p[0].parse()?,
                local_steps: p[1].parse()?,
                comm_rounds: p[2].parse()?,
                sim_time_s: p[3].parse()?,
                train_loss: p[4].parse()?,
                val_loss: p[5].parse()?,
                lr: p[6].parse()?,
            });
        }
        Ok(RunLog {
            tag: path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
            rows,
        })
    }
}

#[derive(Clone, Copy, Debug)]
pub enum Axis {
    CommRounds,
    LocalSteps,
    SimTime,
}

impl Axis {
    fn of(&self, r: &LogRow) -> f64 {
        match self {
            Axis::CommRounds => r.comm_rounds as f64,
            Axis::LocalSteps => r.local_steps as f64,
            Axis::SimTime => r.sim_time_s,
        }
    }
}

/// Render a compact ASCII chart of (x, y) curves — the harness's stand-in
/// for the paper's matplotlib figures.
pub fn ascii_chart(
    title: &str,
    curves: &[(&str, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
) -> String {
    let mut all: Vec<(f64, f64)> = curves.iter().flat_map(|(_, c)| c.iter().copied()).collect();
    all.retain(|(x, y)| x.is_finite() && y.is_finite());
    if all.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (xmin, xmax) =
        all.iter().fold((f64::MAX, f64::MIN), |(lo, hi), &(x, _)| (lo.min(x), hi.max(x)));
    let (ymin, ymax) =
        all.iter().fold((f64::MAX, f64::MIN), |(lo, hi), &(_, y)| (lo.min(y), hi.max(y)));
    let yspan = (ymax - ymin).max(1e-12);
    let xspan = (xmax - xmin).max(1e-12);
    let mut grid = vec![vec![b' '; width]; height];
    let marks = [b'*', b'+', b'o', b'x', b'#', b'@'];
    for (ci, (_, curve)) in curves.iter().enumerate() {
        for &(x, y) in curve {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let col = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let row = height - 1 - (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = marks[ci % marks.len()];
        }
    }
    let mut out = format!("{title}\n");
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:8.3} |")
        } else if i == height - 1 {
            format!("{ymin:8.3} |")
        } else {
            "         |".to_string()
        };
        out.push_str(&label);
        out.push_str(std::str::from_utf8(row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("          +{}\n", "-".repeat(width)));
    out.push_str(&format!("           x: {xmin:.1} .. {xmax:.1}   "));
    for (ci, (name, _)) in curves.iter().enumerate() {
        out.push_str(&format!("[{}] {}  ", marks[ci % marks.len()] as char, name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(round: u64, val: f64) -> LogRow {
        LogRow {
            round,
            local_steps: round * 12,
            comm_rounds: round,
            sim_time_s: round as f64 * 0.5,
            train_loss: 5.0 - round as f64 * 0.1,
            val_loss: val,
            lr: 1e-3,
        }
    }

    #[test]
    fn final_and_best_val() {
        let mut log = RunLog::new("t");
        log.push(row(1, 4.0));
        log.push(row(2, 3.5));
        log.push(row(3, f64::NAN));
        log.push(row(4, 3.7));
        assert_eq!(log.final_val_loss(), Some(3.7));
        assert_eq!(log.best_val_loss(), Some(3.5));
    }

    #[test]
    fn csv_roundtrip() {
        let mut log = RunLog::new("rt");
        log.push(row(1, 4.0));
        log.push(row(2, f64::NAN));
        let dir = std::env::temp_dir().join("dsm_test_metrics");
        let path = dir.join("rt.csv");
        log.write_csv(&path).unwrap();
        let back = RunLog::read_csv(&path).unwrap();
        assert_eq!(back.rows.len(), 2);
        assert_eq!(back.rows[0].round, 1);
        assert!((back.rows[0].val_loss - 4.0).abs() < 1e-9);
        assert!(back.rows[1].val_loss.is_nan());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn curves_respect_axis() {
        let mut log = RunLog::new("ax");
        log.push(row(2, 4.0));
        let c = log.val_curve(Axis::LocalSteps);
        assert_eq!(c, vec![(24.0, 4.0)]);
        let c = log.val_curve(Axis::CommRounds);
        assert_eq!(c, vec![(2.0, 4.0)]);
    }

    #[test]
    fn ascii_chart_renders_all_series() {
        let a = vec![(0.0, 1.0), (1.0, 0.5), (2.0, 0.2)];
        let b = vec![(0.0, 1.0), (1.0, 0.8), (2.0, 0.6)];
        let s = ascii_chart("demo", &[("fast", a), ("slow", b)], 30, 8);
        assert!(s.contains('*') && s.contains('+'));
        assert!(s.contains("fast") && s.contains("slow"));
    }
}
