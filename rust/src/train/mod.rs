//! Training loop: schedules, metrics, checkpoints, and the trainer that
//! wires workers + PJRT runtime + outer optimizers + comm model together.

pub mod checkpoint;
pub mod metrics;
pub mod schedule;
pub mod trainer;

pub use metrics::{LogRow, RunLog};
pub use schedule::{Schedule, ScheduleConfig};
pub use trainer::{RunResult, Trainer};
