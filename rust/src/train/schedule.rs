//! Learning-rate schedules.  The paper uses cosine decay with a linear
//! warm-up (2k steps at full scale) and final LR = 0.05 × peak (§4
//! "Implementations"); warm-up is scaled proportionally here.

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub enum ScheduleConfig {
    Constant { lr: f32 },
    /// Linear warmup to `peak` over `warmup` steps, then cosine decay to
    /// `final_frac * peak` at `total` steps.
    Cosine { peak: f32, final_frac: f32, warmup: u64, total: u64 },
}

impl ScheduleConfig {
    /// Paper schedule scaled to a run of `total` local steps: warmup is
    /// 2% of the run (the paper's 2k/100k), floor at final 5% of peak.
    pub fn cosine_paper(peak: f32, total: u64) -> ScheduleConfig {
        ScheduleConfig::Cosine {
            peak,
            final_frac: 0.05,
            warmup: (total / 50).max(1),
            total: total.max(2),
        }
    }

    pub fn from_json(v: &Json, default_total: u64) -> Result<ScheduleConfig, String> {
        let kind = v.get("kind").and_then(Json::as_str).unwrap_or("cosine");
        let f = |key: &str, default: f32| -> f32 {
            v.get(key).and_then(Json::as_f64).map(|x| x as f32).unwrap_or(default)
        };
        match kind {
            "constant" => Ok(ScheduleConfig::Constant { lr: f("lr", 1e-3) }),
            "cosine" => Ok(ScheduleConfig::Cosine {
                peak: f("peak", 1e-3),
                final_frac: f("final_frac", 0.05),
                warmup: v
                    .get("warmup")
                    .and_then(Json::as_usize)
                    .map(|x| x as u64)
                    .unwrap_or((default_total / 50).max(1)),
                total: v
                    .get("total")
                    .and_then(Json::as_usize)
                    .map(|x| x as u64)
                    .unwrap_or(default_total),
            }),
            other => Err(format!("unknown schedule `{other}`")),
        }
    }

    /// Re-point the schedule horizon (CLI may change rounds/tau after the
    /// schedule was first constructed).
    pub fn retarget_total(&mut self, new_total: u64) {
        if let ScheduleConfig::Cosine { total, warmup, .. } = self {
            *total = new_total.max(2);
            *warmup = (*warmup).min(new_total / 2).max(1);
        }
    }

    pub fn total_steps(&self) -> u64 {
        match self {
            ScheduleConfig::Constant { .. } => u64::MAX,
            ScheduleConfig::Cosine { total, .. } => *total,
        }
    }

    pub fn build(&self) -> Schedule {
        Schedule { cfg: self.clone() }
    }
}

#[derive(Clone, Debug)]
pub struct Schedule {
    cfg: ScheduleConfig,
}

impl Schedule {
    /// γ_t for local step index `step` (0-based).
    pub fn lr(&self, step: u64) -> f32 {
        match self.cfg {
            ScheduleConfig::Constant { lr } => lr,
            ScheduleConfig::Cosine { peak, final_frac, warmup, total } => {
                if step < warmup {
                    // linear 0 -> peak, never exactly 0 (step+1)
                    return peak * (step + 1) as f32 / warmup as f32;
                }
                let t = (step - warmup) as f64 / (total.saturating_sub(warmup)).max(1) as f64;
                let t = t.min(1.0);
                let floor = (peak * final_frac) as f64;
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * t).cos());
                (floor + (peak as f64 - floor) * cos) as f32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::toml;

    #[test]
    fn warmup_rises_linearly_then_decays() {
        let s = ScheduleConfig::Cosine { peak: 1.0, final_frac: 0.1, warmup: 10, total: 100 }
            .build();
        assert!((s.lr(0) - 0.1).abs() < 1e-6);
        assert!((s.lr(9) - 1.0).abs() < 1e-6);
        assert!(s.lr(9) >= s.lr(50));
        assert!(s.lr(50) > s.lr(99));
        // final LR = final_frac * peak
        assert!((s.lr(99) - 0.1).abs() < 0.02, "{}", s.lr(99));
        // never below the floor, even past the horizon
        assert!(s.lr(10_000) >= 0.1 - 1e-6);
    }

    #[test]
    fn never_zero() {
        let s = ScheduleConfig::cosine_paper(5e-4, 300).build();
        for t in 0..400 {
            assert!(s.lr(t) > 0.0, "step {t}");
        }
    }

    #[test]
    fn constant_is_constant() {
        let s = ScheduleConfig::Constant { lr: 0.25 }.build();
        assert_eq!(s.lr(0), 0.25);
        assert_eq!(s.lr(1_000_000), 0.25);
    }

    #[test]
    fn paper_defaults_proportions() {
        // 2% warmup of the paper's 100k = 2k steps.
        match ScheduleConfig::cosine_paper(5e-4, 100_000) {
            ScheduleConfig::Cosine { warmup, final_frac, .. } => {
                assert_eq!(warmup, 2000);
                assert_eq!(final_frac, 0.05);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn from_json_and_retarget() {
        let t = toml::parse("kind = \"cosine\"\npeak = 0.01\nwarmup = 5\n").unwrap();
        let mut cfg = ScheduleConfig::from_json(&t, 200).unwrap();
        assert_eq!(cfg.total_steps(), 200);
        cfg.retarget_total(50);
        assert_eq!(cfg.total_steps(), 50);
        assert!(ScheduleConfig::from_json(&toml::parse("kind = \"x\"").unwrap(), 1).is_err());
    }
}
