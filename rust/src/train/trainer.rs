//! The coordinator loop: Algorithm 1's outer structure with pluggable
//! base/outer optimizers, standalone per-step baselines, modeled
//! communication, validation, and logging.
//!
//! One `Trainer` drives n simulated workers through T outer rounds of τ
//! local steps each.  The backend ([`StepBackend`]: PJRT executables or
//! the native MLP LM) does the real compute; everything around it —
//! sharded batch sampling, base optimizer steps, the typed round
//! exchange, the global sign-momentum step — is native Rust on the flat
//! f32[P] vector.
//!
//! # The round exchange
//!
//! Every outer round runs ONE generic exchange, whatever the wire
//! format: the trainer keeps n persistent [`WirePayload`] buffers
//! (checked against the round's format/dimension and re-initialized on
//! mismatch), bills the clock from the payloads' own
//! [`WirePayload::wire_bytes`] ([`SimClock::charge_exchange`] — billing
//! precedes packing, which both fixes the byte count independent of
//! contents and keeps the trainer RNG order of the historical
//! semantics: straggler draw first, then per-rank randomized-sign
//! draws), has each rank pack its contribution
//! ([`crate::outer::OuterOptimizer::contribute`], rank order), and
//! hands the payloads to the server-side
//! [`crate::outer::OuterOptimizer::apply`]. There is no per-format
//! branch left in this file: adding a wire format touches
//! [`crate::dist::wire`], not the trainer. The buffers are sized from
//! the backend's validated [`ParamLayout`]
//! ([`StepBackend::layout`]) — the layout-aware `q8pt` format carries
//! one quantization scale per segment, the sparse `topk` format
//! carries per-segment component budgets plus the rank's persistent
//! residual-momentum buffer (worker state riding in the payload, saved
//! as `worker{w}.topk_residual` so resume is bit-identical); every
//! other format just takes
//! the coordinate count. After each apply the trainer resolves the
//! global update along the same layout
//! ([`crate::train::metrics::segment_norms`]) so experiments can show
//! where the bits go.
//!
//! # Parallel fleet execution
//!
//! The n simulated ranks of one round execute **concurrently** on the
//! persistent pool ([`crate::dist::pool::run_indexed_mut`]): each rank
//! job owns a disjoint `&mut Worker` (its iterate, RNG substream, and
//! base-optimizer state) and shares the compiled backend through the
//! `Send + Sync` contract on [`StepBackend`]. This is bitwise-identical
//! to the serial loop — per-rank arithmetic is unchanged, per-rank
//! results are gathered by rank index, and the trainer RNG is only
//! consumed on the coordinator after the fleet joins — so loss curves,
//! checkpoints, and RNG streams match the `cfg.sequential_workers`
//! reference path to the last bit (`rust/tests/parallel_fleet.rs`).
//! Only wall-clock changes: one round costs ~max(rank) instead of
//! Σ(rank) (`benches/trainer.rs` records the speedup). The measured
//! per-rank compute seconds that feed `SimClock` are wall clock, so
//! concurrent ranks can include host-contention inflation — see
//! `SimClock::charge_parallel_compute` and `cfg.sequential_workers`
//! for the uncontended-measurement reference.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::comm::{Attack, FaultStats, SimClock, Topology};
use crate::config::{RunConfig, TrainMode};
use crate::data::corpus::{self, CorpusConfig};
use crate::data::dataset::{Batch, TokenDataset};
use crate::data::tokenizer::ByteTokenizer;
use crate::dist::{collectives, pool, AggPolicy, WireFormat, WirePayload, Worker};
use crate::outer::{OuterConfig, OuterOptimizer, RoundCtx, WorkerView};
use crate::runtime::{Artifacts, ParamLayout, Runtime, SignUpdateKernel, StepBackend};
use crate::sign::SignOp;
use crate::tensor;
use crate::train::checkpoint::Checkpoint;
use crate::train::metrics::{self, LogRow, RunLog, SegmentNorm};
use crate::train::schedule::Schedule;
use crate::util::rng::Rng;

pub struct Trainer {
    pub cfg: RunConfig,
    backend: Arc<dyn StepBackend>,
    dataset: TokenDataset,
    workers: Vec<Worker>,
    global: Vec<f32>,
    outer: Box<dyn OuterOptimizer>,
    schedule: Schedule,
    clock: SimClock,
    rng: Rng,
    /// Dedicated checkpointed stream for everything fault- and
    /// network-jitter-shaped: straggler barrier draws, membership
    /// churn, drops, corruption. Kept apart from the training stream
    /// (`rng`) so toggling stragglers or faults can never shift an
    /// optimization draw — [`crate::comm::CommModel::straggler_delay`]
    /// consumes nothing when jitter is off, so only this stream's
    /// position varies with the comm preset.
    fault_rng: Rng,
    /// What the fault plan actually did, accumulated over the run
    /// (checkpointed; all-zero when faults are off).
    faults: FaultStats,
    /// Byzantine membership: ⌊byzantine_frac·n⌋ ranks drawn once per
    /// run on the fault stream at construction, so the set is a pure
    /// function of the seed and survives checkpoint resume without
    /// being stored. All-false — and zero draws — when the knob is off.
    adversaries: Vec<bool>,
    /// Per-rank reputation in [0, 1] held by the quarantine supervisor:
    /// exponential decay toward each scored round's good/bad verdict
    /// (norm z-score + sign agreement against the applied update).
    /// Only [`crate::comm::FaultPlan::quarantine`] scores rounds.
    rep: Vec<f64>,
    /// Rounds each rank still sits out. A positive entry freezes the
    /// rank exactly like churn absence (worker RNG and base-optimizer
    /// state untouched); expiry re-admits it on probation.
    quarantine_left: Vec<u64>,
    /// Current quarantine duration per rank — doubles on every relapse
    /// (exponential backoff for repeat offenders).
    backoff: Vec<u64>,
    val_batches: Vec<Batch>,
    /// The round exchange's wire format (config override or the outer
    /// optimizer's native format — [`RunConfig::resolved_wire`]).
    wire: WireFormat,
    /// Persistent per-rank payload buffers: re-packed in place every
    /// round, so the steady-state exchange allocates nothing in any
    /// wire format. Checked and re-initialized (never asserted) when
    /// the round's (fleet size, format, dimension) disagrees.
    payloads: Vec<WirePayload>,
    /// The backend's validated parameter layout, shared with every
    /// worker and (for the `q8pt` wire) every payload buffer
    /// ([`StepBackend::layout`]).
    layout: Arc<ParamLayout>,
    /// Per-segment norms of the most recent round's global update
    /// (`start → global`), resolved along `layout` — the
    /// "where the bits go" signal the experiments surface.
    last_seg_norms: Vec<SegmentNorm>,
    log: RunLog,
    local_step: u64,
    round: u64,
}

/// Run one closure per rank over the whole fleet — concurrently on the
/// persistent pool by default, serially on the calling thread when
/// `sequential` asks for the reference path — gathering the per-rank
/// results in rank order. The two execution modes are bitwise-identical
/// by construction: each job touches only its own `Worker` plus shared
/// read-only state (backend, dataset, schedule), and the trainer RNG is
/// never consumed inside a job.
fn run_fleet<R, F>(sequential: bool, workers: &mut [Worker], job: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, &mut Worker) -> R + Sync,
{
    if sequential {
        workers.iter_mut().enumerate().map(|(w, worker)| job(w, worker)).collect()
    } else {
        pool::run_indexed_mut(workers, job)
    }
}

/// Median of an unordered slice (0.0 when empty) — supervisor-side
/// robust statistics, f64 throughout, no RNG.
fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

pub struct RunResult {
    pub log: RunLog,
    pub clock: SimClock,
    pub final_val: f64,
    pub best_val: f64,
    /// Per-segment norms of the last round's global update (empty in
    /// standalone mode) — see [`Trainer::segment_norms`].
    pub segment_norms: Vec<SegmentNorm>,
    /// Injected-fault bookkeeping (all-zero when the fault plan is
    /// inactive) — see [`crate::comm::FaultStats`].
    pub faults: FaultStats,
}

impl Trainer {
    // Supervisor tuning (see `score_survivors`): a survivor is flagged
    // when its diff norm sits more than Z_THRESH robust standard
    // deviations from the survivor median, or when fewer than
    // AGREE_THRESH of its transmitted coordinates agree in sign with
    // the applied update. Reputation halves toward each verdict;
    // crossing REP_QUARANTINE freezes the rank for QUARANTINE_BASE
    // rounds (doubling per relapse), and expiry re-admits it at
    // REP_PROBATION — one bad round from relapsing.
    const Z_THRESH: f64 = 4.0;
    const AGREE_THRESH: f64 = 0.2;
    const REP_QUARANTINE: f64 = 0.4;
    const REP_PROBATION: f64 = 0.6;
    const QUARANTINE_BASE: u64 = 4;

    pub fn new(cfg: RunConfig, rt: &Runtime, arts: &Artifacts) -> Result<Trainer> {
        let info = arts.preset(&cfg.preset)?;
        let bundle = Arc::new(crate::runtime::ModelBundle::load(rt, info)?);
        Trainer::with_bundle(cfg, bundle, rt, arts)
    }

    /// Build a trainer around an already-compiled bundle (the experiment
    /// harness shares one compiled bundle per preset across dozens of runs
    /// — XLA compilation costs ~15 s per preset on this host). `rt`/`arts`
    /// are only consulted for the optional Pallas global-step kernel,
    /// which is installed as an `apply` specialization on the
    /// [`crate::outer::SignMomentum`] outer optimizer
    /// ([`crate::outer::SignMomentum::with_kernel`]) — the kernel path
    /// shares the optimizer's checkpointed momentum and the trainer has
    /// no per-kernel branch.
    pub fn with_bundle(
        cfg: RunConfig,
        bundle: Arc<dyn StepBackend>,
        rt: &Runtime,
        arts: &Artifacts,
    ) -> Result<Trainer> {
        let outer_override: Option<Box<dyn OuterOptimizer>> = if cfg.global_step_pallas {
            let p = bundle.info().param_count;
            let Some(sm) = cfg.outer.build_sign_momentum(p) else {
                anyhow::bail!("--pallas-global-step requires the sign_momentum outer optimizer");
            };
            anyhow::ensure!(
                matches!(cfg.outer, OuterConfig::SignMomentum { sign_op: SignOp::Exact, .. }),
                "the Pallas sign-update kernel implements the exact sign operator only"
            );
            let kernel = SignUpdateKernel::load(rt, arts)?;
            Some(Box::new(sm.with_kernel(kernel)))
        } else {
            None
        };
        Trainer::build(cfg, bundle, outer_override)
    }

    /// Build a trainer over any [`StepBackend`] — e.g. the pure-Rust
    /// [`crate::runtime::NativeBundle`] — with no PJRT runtime or
    /// artifacts directory required. The Pallas global-step path needs
    /// the AOT'd kernel, so it is only reachable through
    /// [`Trainer::with_bundle`].
    pub fn with_backend(cfg: RunConfig, backend: Arc<dyn StepBackend>) -> Result<Trainer> {
        anyhow::ensure!(
            !cfg.global_step_pallas,
            "--pallas-global-step requires Trainer::with_bundle (AOT'd kernel)"
        );
        Trainer::build(cfg, backend, None)
    }

    fn build(
        cfg: RunConfig,
        bundle: Arc<dyn StepBackend>,
        outer_override: Option<Box<dyn OuterOptimizer>>,
    ) -> Result<Trainer> {
        cfg.validate()?;
        anyhow::ensure!(bundle.info().name == cfg.preset, "bundle/preset mismatch");
        // Best-effort benchmarking knob: helpers pin at spawn, so this
        // only takes effect if set before the process first touches the
        // global pool (thread placement cannot change any trajectory).
        pool::set_pin_workers(cfg.pin_workers);
        let info = bundle.info();
        let p = info.param_count;
        // the layout contract: validated at backend construction, so a
        // mismatch here is a backend bug, not a config error
        let layout = Arc::new(bundle.layout().clone());
        anyhow::ensure!(
            layout.param_count() == p,
            "backend layout tiles {} of {} params",
            layout.param_count(),
            p
        );

        // data: deterministic synthetic corpus, byte tokenizer, n shards.
        // In heterogeneous mode the training region is built from one
        // differently-weighted segment per worker (non-IID shards), while
        // the validation tail keeps the default mixture so every method
        // is scored on the same balanced distribution.
        let text = if cfg.heterogeneous {
            let train_bytes =
                ((cfg.corpus_bytes as f64) * (1.0 - cfg.val_fraction)) as usize;
            let mut t = corpus::generate_heterogeneous(
                train_bytes,
                cfg.seed ^ 0xC0FFEE,
                cfg.n_workers,
            );
            t.extend(corpus::generate(&CorpusConfig {
                bytes: cfg.corpus_bytes - train_bytes,
                seed: cfg.seed ^ 0xBEEF,
                ..Default::default()
            }));
            t
        } else {
            corpus::generate(&CorpusConfig {
                bytes: cfg.corpus_bytes,
                seed: cfg.seed ^ 0xC0FFEE,
                ..Default::default()
            })
        };
        let dataset = TokenDataset::from_text(&ByteTokenizer, &text, cfg.val_fraction);
        let val_batches = dataset.val_batches(info.batch, info.seq, cfg.eval_batches);
        anyhow::ensure!(!val_batches.is_empty(), "validation split too small");

        let root_rng = Rng::new(cfg.seed);
        // Byzantine membership: drawn once on the dedicated fault
        // stream, before round 0. With the knob off nothing is drawn —
        // the stream position (and every clean trajectory) is untouched
        // — and on resume the same membership re-derives from the seed
        // before the checkpointed stream position is restored on top.
        let mut fault_rng = root_rng.substream("faults", 0);
        let n_adversaries =
            (cfg.faults.byzantine_frac * cfg.n_workers as f64).floor() as usize;
        let mut adversaries = vec![false; cfg.n_workers];
        if n_adversaries > 0 {
            let mut ranks: Vec<usize> = (0..cfg.n_workers).collect();
            fault_rng.shuffle(&mut ranks);
            for &r in &ranks[..n_adversaries] {
                adversaries[r] = true;
            }
        }
        let workers: Vec<Worker> = (0..cfg.n_workers)
            .map(|i| Worker::new(i, Arc::clone(&layout), &cfg.base, &root_rng))
            .collect();

        let global = bundle.init_params(cfg.seed as u32)?;
        let outer = match outer_override {
            Some(outer) => outer,
            None => cfg.outer.build(p),
        };

        Ok(Trainer {
            schedule: cfg.schedule.build(),
            log: RunLog::new(&cfg.tag),
            rng: root_rng.substream("trainer", 0),
            fault_rng,
            faults: FaultStats::default(),
            adversaries,
            rep: vec![1.0; cfg.n_workers],
            quarantine_left: vec![0; cfg.n_workers],
            backoff: vec![0; cfg.n_workers],
            wire: cfg.resolved_wire(),
            cfg,
            backend: bundle,
            dataset,
            workers,
            global,
            outer,
            clock: SimClock::default(),
            val_batches,
            payloads: Vec::new(),
            layout,
            last_seg_norms: Vec::new(),
            local_step: 0,
            round: 0,
        })
    }

    pub fn params(&self) -> &[f32] {
        &self.global
    }

    /// The backend's validated parameter layout this run follows.
    pub fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    /// Per-segment norms of the most recent outer round's global
    /// update (empty before the first round and in standalone mode,
    /// which has no round exchange).
    pub fn segment_norms(&self) -> &[SegmentNorm] {
        &self.last_seg_norms
    }

    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    /// Injected-fault bookkeeping so far (all-zero when the plan is
    /// inactive).
    pub fn fault_stats(&self) -> &FaultStats {
        &self.faults
    }

    /// Which ranks the fault plan made Byzantine — all-false when
    /// `byzantine_frac` is 0. Drawn once per run on the fault stream
    /// ([`crate::comm::FaultPlan::byzantine_frac`]).
    pub fn adversaries(&self) -> &[bool] {
        &self.adversaries
    }

    /// Per-rank reputation held by the quarantine supervisor (all 1.0
    /// until `[faults] quarantine` scores a round).
    pub fn reputations(&self) -> &[f64] {
        &self.rep
    }

    /// Rounds each rank still sits out under quarantine (0 = active).
    pub fn quarantine_rounds_left(&self) -> &[u64] {
        &self.quarantine_left
    }

    /// Test/ops hook: freeze `rank` for the next `rounds` outer rounds
    /// exactly as the reputation supervisor would — worker RNG and
    /// base-optimizer state untouched, the slot billed as absent,
    /// re-admission on probation when the clock runs out. The
    /// churn-freeze equivalence tests drive this directly, without a
    /// fault plan.
    pub fn force_quarantine(&mut self, rank: usize, rounds: u64) {
        self.quarantine_left[rank] = rounds;
    }

    pub fn dim(&self) -> usize {
        self.global.len()
    }

    /// Run all configured rounds, returning the curves and final metrics.
    pub fn run(&mut self) -> Result<RunResult> {
        self.run_with_progress(|_| {})
    }

    pub fn run_with_progress<F: FnMut(&LogRow)>(&mut self, mut progress: F) -> Result<RunResult> {
        while self.round < self.cfg.rounds as u64 {
            let row = self.step_round()?;
            progress(&row);
        }
        let final_val = match self.log.final_val_loss() {
            Some(v) => v,
            None => self.evaluate()?,
        };
        Ok(RunResult {
            log: self.log.clone(),
            clock: self.clock.clone(),
            final_val,
            best_val: self.log.best_val_loss().unwrap_or(final_val),
            segment_norms: self.last_seg_norms.clone(),
            faults: self.faults,
        })
    }

    /// Execute one outer round (or one standalone step when tau == 1 in
    /// standalone mode), returning the log row it produced.
    pub fn step_round(&mut self) -> Result<LogRow> {
        match self.cfg.mode {
            TrainMode::LocalSteps => self.local_round(),
            TrainMode::Standalone => self.standalone_step(),
        }?;
        self.round += 1;

        // evaluate on schedule (always on the final round)
        let do_eval = self.cfg.eval_every > 0 && self.round % self.cfg.eval_every as u64 == 0
            || self.round == self.cfg.rounds as u64;
        let val_loss = if do_eval { self.evaluate()? } else { f64::NAN };

        let train_loss = {
            let mut acc = 0.0;
            let mut n = 0;
            for w in &mut self.workers {
                let l = w.take_mean_loss();
                if !l.is_nan() {
                    acc += l;
                    n += 1;
                }
            }
            if n == 0 {
                f64::NAN
            } else {
                acc / n as f64
            }
        };

        let row = LogRow {
            round: self.round,
            local_steps: self.local_step,
            comm_rounds: self.clock.comm_rounds,
            sim_time_s: self.clock.total_s(),
            train_loss,
            val_loss,
            lr: self.schedule.lr(self.local_step.saturating_sub(1)),
        };
        self.log.push(row.clone());
        Ok(row)
    }

    /// One round of Algorithm 1's outer loop (lines 3-11), with the
    /// optional fault plan wrapped around the exchange. All fault
    /// draws come from the dedicated checkpointed `fault_rng`; with
    /// [`crate::comm::FaultPlan::none`] the round takes the exact
    /// pre-fault code path — no extra draws, no payload copies — so
    /// every bit-identity invariant is preserved by construction.
    fn local_round(&mut self) -> Result<()> {
        let n = self.cfg.n_workers;
        let p = self.global.len();
        let tau = self.cfg.tau;
        let plan = self.cfg.faults;
        let faults_on = plan.is_active();
        // γ_t for the outer step: LR at the round's first local step.
        let gamma_t = self.schedule.lr(self.local_step);

        let start = self.outer.local_start(&self.global);

        // Elastic membership: each rank sits the round out with
        // churn_prob (at least one rank always trains). An absent rank
        // skips its local phase entirely — its worker RNG and base-
        // optimizer state freeze until it rejoins, and rejoining is
        // trivially consistent because every round starts by copying
        // the broadcast `start` into the rank's iterate.
        let mut active: Vec<bool> = if faults_on && plan.churn_prob > 0.0 {
            let mut a: Vec<bool> =
                (0..n).map(|_| !self.fault_rng.bernoulli(plan.churn_prob)).collect();
            if !a.iter().any(|&x| x) {
                a[self.fault_rng.below(n as u64) as usize] = true;
            }
            a
        } else {
            vec![true; n]
        };
        // Reputation quarantine rides the same freeze: a quarantined
        // rank sits the round out exactly like churn absence (worker
        // RNG and base-optimizer state untouched, slot billed as
        // absent). Quarantine is capped below n/2 ranks, so a clean
        // rank always exists for the liveness guard — picked
        // deterministically, no fault-stream draw.
        if self.quarantine_left.iter().any(|&q| q > 0) {
            for w in 0..n {
                if self.quarantine_left[w] > 0 {
                    active[w] = false;
                }
            }
            if !active.iter().any(|&x| x) {
                let Some(w) = (0..n).find(|&w| self.quarantine_left[w] == 0) else {
                    unreachable!("quarantine is capped below the fleet size")
                };
                active[w] = true;
            }
        }
        // tick the quarantine clocks: expiry re-admits on probation
        for w in 0..n {
            if self.quarantine_left[w] > 0 {
                self.quarantine_left[w] -= 1;
                if self.quarantine_left[w] == 0 {
                    self.rep[w] = Self::REP_PROBATION;
                    self.faults.readmissions += 1;
                }
            }
        }
        let n_active = active.iter().filter(|&&x| x).count();
        self.faults.absent_ranks += (n - n_active) as u64;

        // Lines 4-7: every present rank runs its τ-step local phase.
        // The jobs fan out onto the pool; each returns its measured
        // compute seconds (or the first error it hit), gathered by
        // rank index. Absent ranks return 0 s without touching their
        // worker.
        let per_rank: Vec<Result<f64>> = {
            let backend = &self.backend;
            let dataset = &self.dataset;
            let schedule = &self.schedule;
            let start = &start;
            let active = &active;
            let (batch_sz, seq) = {
                let info = backend.info();
                (info.batch, info.seq)
            };
            let (base_step, round) = (self.local_step, self.round);
            let sequential = self.cfg.sequential_workers;
            run_fleet(sequential, &mut self.workers, move |w, worker| -> Result<f64> {
                if !active[w] {
                    return Ok(0.0);
                }
                worker.params.copy_from_slice(start);
                let mut secs = 0.0f64;
                for k in 0..tau {
                    let lr = schedule.lr(base_step + k as u64);
                    let batch = dataset.sample_train(w, n, batch_sz, seq, &mut worker.rng);
                    let t0 = Instant::now();
                    let out = backend.train_step(&worker.params, &batch)?;
                    secs += t0.elapsed().as_secs_f64();
                    anyhow::ensure!(
                        out.loss.is_finite(),
                        "worker {w} diverged at round {round} (loss={})",
                        out.loss
                    );
                    worker.observe(out.loss, &out.grads);
                    worker.opt.step(&mut worker.params, &out.grads, lr);
                }
                Ok(secs)
            })
        };
        let mut per_worker_secs = Vec::with_capacity(n);
        for r in per_rank {
            per_worker_secs.push(r?);
        }
        self.local_step += tau as u64;
        self.clock.charge_parallel_compute(&per_worker_secs);

        // Heavy-tailed stragglers: with tail_prob per present rank, a
        // Pareto(α)-distributed stall on top of the comm model's
        // lognormal jitter. The round barrier waits for the slowest
        // rank, so the clock pays the worst stall.
        if faults_on && plan.tail_prob > 0.0 {
            let mut worst = 0.0f64;
            for _ in 0..n_active {
                if self.fault_rng.bernoulli(plan.tail_prob) {
                    worst = worst.max(plan.tail_scale_s * self.fault_rng.pareto(plan.tail_alpha));
                }
            }
            self.clock.straggler_s += worst;
        }

        // Transit drops among the present ranks: a dropped payload
        // never reaches the aggregation point (not billed on the
        // down-leg it never earned, not aggregated). The rank itself
        // still packs below — the loss happens after contribution, so
        // the training RNG order is independent of drop draws.
        let mut arrived_mask: Vec<bool> = if faults_on && plan.drop_prob > 0.0 {
            active.iter().map(|&a| a && !self.fault_rng.bernoulli(plan.drop_prob)).collect()
        } else {
            active.clone()
        };
        // Bounded retransmission: every dropped payload is re-sent up
        // to retry_limit times, each attempt an independent drop draw
        // on the fault stream. Only the copy that finally arrives is
        // billed (a failed attempt vanishes in transit exactly like
        // the original send); every re-send attempt is counted.
        if faults_on && plan.retry_limit > 0 && plan.drop_prob > 0.0 {
            for w in 0..n {
                if !active[w] || arrived_mask[w] {
                    continue;
                }
                for _ in 0..plan.retry_limit {
                    self.faults.retried_payloads += 1;
                    if !self.fault_rng.bernoulli(plan.drop_prob) {
                        arrived_mask[w] = true;
                        break;
                    }
                }
            }
        }
        let arrived = arrived_mask.iter().filter(|&&x| x).count();
        self.faults.dropped_payloads += (n_active - arrived) as u64;

        // The round exchange — ONE generic typed-payload path for every
        // outer optimizer and wire format (lines 8-10):
        //
        // (1) persistent per-rank payload buffers, checked against the
        //     round's (fleet size, format, dimension) and re-initialized
        //     on any mismatch — e.g. the first round, or a config change
        //     across a checkpoint resume — instead of asserting;
        // (2) the clock bills the payloads' own wire_bytes. Billing
        //     precedes packing: the byte count is a function of
        //     (format, dimension) only — never of the packed contents —
        //     and charging first keeps the trainer RNG order of the
        //     historical semantics (straggler draw, then per-rank
        //     randomized-sign draws);
        // (3) worker side: each rank packs its contribution, rank order;
        // (4) any size/format drift during packing is an error — the
        //     billed cost and the exchanged data may not diverge;
        // (5) server side: apply the global step from the payloads.
        self.ensure_payload_buffers();
        // billing: with a full fleet this is bitwise charge_exchange
        // (Topology::select routes ring / flat / hierarchical); a
        // degraded round bills exactly what moved — `arrived − 1` up,
        // `n_active − 1` down. Straggler draws come from fault_rng
        // (dedicated stream; nothing is drawn when jitter is off).
        self.clock.charge_exchange_among(
            &self.cfg.comm,
            n_active,
            arrived,
            &self.payloads[0],
            &mut self.fault_rng,
        );
        // Total transit loss: nothing reached the aggregation point.
        // Pinned held-round semantics: the round holds at `start` — no
        // contribution is packed (the trainer RNG is not consumed), the
        // outer-optimizer state does not advance, no scoring runs — but
        // the τ local steps, the LR schedule, and the exchange billing
        // above all stand.
        if arrived == 0 {
            self.faults.no_quorum_rounds += 1;
            self.global.copy_from_slice(&start);
            self.last_seg_norms = metrics::segment_norms(&self.layout, &start, &self.global);
            return Ok(());
        }
        for w in 0..n {
            if !active[w] {
                continue; // absent ranks have nothing to pack
            }
            let view = WorkerView {
                start: &start,
                end: &self.workers[w].params,
                last_grad: &self.workers[w].last_grad,
                layout: &self.layout,
            };
            self.outer.contribute(w, n, &view, &mut self.rng, &mut self.payloads[w]);
        }
        for (w, pl) in self.payloads.iter().enumerate() {
            anyhow::ensure!(
                pl.format() == self.wire && pl.len() == p,
                "worker {w}: contribute produced a {}[{}] payload where the round billed {}[{}]",
                pl.format().name(),
                pl.len(),
                self.wire.name(),
                p
            );
        }
        // corruption in transit: each arriving payload is damaged with
        // corrupt_prob — a flipped byte/sign/index bit (valid encoding,
        // survived with bounded error) or a NaN-poisoned scale,
        // coordinate, or sparse value (rejected by the finiteness check
        // below). The counter follows corrupt()'s report, so it counts
        // injections that actually landed — never attempts that had
        // nothing to damage.
        // Adversary injection: each Byzantine rank corrupts its OWN
        // contribution at the source — after honest packing, before
        // transit corruption. Every attacked payload stays finite and
        // decodable (a Byzantine rank is a liar, not a crash), so only
        // a robust `agg` policy, the sign tally, or the quarantine
        // supervisor can defend. The flaky coin is tossed for every
        // adversary on every non-held round, whether or not its payload
        // arrived, so the fault-stream draw count never depends on
        // churn or drop outcomes.
        let mut byz_applied = vec![false; n];
        if faults_on && plan.byzantine_frac > 0.0 {
            for w in 0..n {
                if !self.adversaries[w] {
                    continue;
                }
                let attack = match plan.attack {
                    Attack::Flaky => {
                        if self.fault_rng.bernoulli(0.5) {
                            Some(Attack::SignFlip)
                        } else {
                            None
                        }
                    }
                    a => Some(a),
                };
                match attack {
                    Some(a) if arrived_mask[w] => {
                        self.payloads[w].byzantine(a, &start);
                        byz_applied[w] = true;
                    }
                    _ => {}
                }
            }
        }
        if faults_on && plan.corrupt_prob > 0.0 {
            for w in 0..n {
                if arrived_mask[w]
                    && self.fault_rng.bernoulli(plan.corrupt_prob)
                    && self.payloads[w].corrupt(&mut self.fault_rng)
                {
                    self.faults.corrupted_payloads += 1;
                }
            }
        }
        let ctx =
            RoundCtx { start: &start, gamma: gamma_t, round: self.round, agg: self.cfg.agg };
        self.global.copy_from_slice(&start);
        if !faults_on && n_active == n {
            // the clean path: all n payloads, zero copies, bitwise-
            // pinned. At hierarchical scale the group heads partially
            // aggregate first; the outer optimizer consumes the
            // replicated head payloads through its unchanged interface
            // (a group-size-weighted mean/tally by construction). A
            // non-finite scale from a diverged rank is a hard error
            // here — with no fault plan there is nothing to survive.
            match Topology::select(self.payloads[0].ring_reducible(), n) {
                Topology::Hierarchical { groups } => {
                    let heads = WirePayload::aggregate_group_heads(
                        &self.payloads,
                        groups,
                        self.cfg.agg,
                    );
                    self.outer.apply(&mut self.global, &ctx, &heads, &mut self.rng)?;
                }
                _ => {
                    self.outer.apply(&mut self.global, &ctx, &self.payloads, &mut self.rng)?;
                }
            }
        } else {
            // Degraded membership — a fault plan, or a quarantine
            // freeze with no plan at all. n_effective: the arrived
            // payloads that pass the finiteness check. Rejections are
            // counted, never averaged in; a round with no survivors
            // holds the global at the round start (outer state
            // untouched) instead of erroring.
            let mut survivors: Vec<WirePayload> = Vec::with_capacity(arrived);
            let mut survivor_ranks: Vec<usize> = Vec::with_capacity(arrived);
            for w in 0..n {
                if !arrived_mask[w] {
                    continue;
                }
                match self.payloads[w].check_finite(w) {
                    Ok(()) => {
                        survivors.push(self.payloads[w].clone());
                        survivor_ranks.push(w);
                    }
                    Err(_) => self.faults.rejected_payloads += 1,
                }
            }
            if survivors.is_empty() {
                self.faults.no_quorum_rounds += 1;
            } else {
                let topo = Topology::select(survivors[0].ring_reducible(), survivors.len());
                let heads;
                let agg: &[WirePayload] = match topo {
                    Topology::Hierarchical { groups } => {
                        heads = WirePayload::aggregate_group_heads(
                            &survivors,
                            groups,
                            self.cfg.agg,
                        );
                        &heads
                    }
                    _ => &survivors,
                };
                self.outer.apply(&mut self.global, &ctx, agg, &mut self.rng)?;
                if survivor_ranks.iter().any(|&w| byz_applied[w]) {
                    self.faults.byzantine_rounds_survived += 1;
                }
                if plan.quarantine {
                    self.score_survivors(&start, &survivor_ranks);
                }
            }
        }
        anyhow::ensure!(tensor::all_finite(&self.global), "global params diverged");
        // resolve this round's global update along the layout (pure
        // observation: no RNG, no parameter writes — trajectories are
        // untouched; one O(P) pass, negligible next to the τ fwd+bwd
        // steps each rank just ran, and it keeps `segment_norms()`
        // current for callers driving `step_round` themselves)
        self.last_seg_norms = metrics::segment_norms(&self.layout, &start, &self.global);
        Ok(())
    }

    /// One step of the standalone baseline: per-step gradient all-reduce,
    /// single shared optimizer (the paper's "AdamW / Sophia" rows). The
    /// per-rank gradient passes fan out onto the pool exactly like
    /// `local_round`'s local phases.
    fn standalone_step(&mut self) -> Result<()> {
        let n = self.cfg.n_workers;
        let lr = self.schedule.lr(self.local_step);
        let per_rank: Vec<Result<(f64, Vec<f32>)>> = {
            let backend = &self.backend;
            let dataset = &self.dataset;
            let global = &self.global;
            let (batch_sz, seq) = {
                let info = backend.info();
                (info.batch, info.seq)
            };
            run_fleet(self.cfg.sequential_workers, &mut self.workers, move |w, worker| {
                let batch = dataset.sample_train(w, n, batch_sz, seq, &mut worker.rng);
                let t0 = Instant::now();
                let out = backend.train_step(global, &batch)?;
                let secs = t0.elapsed().as_secs_f64();
                worker.observe(out.loss, &out.grads);
                Ok((secs, out.grads))
            })
        };
        let mut per_worker_secs = Vec::with_capacity(n);
        let mut grads = Vec::with_capacity(n);
        for r in per_rank {
            let (secs, g) = r?;
            per_worker_secs.push(secs);
            grads.push(g);
        }
        let mut mean_grad = vec![0.0f32; self.global.len()];
        collectives::allreduce_mean(&grads, |g| g.as_slice(), &mut mean_grad);
        self.clock.charge_parallel_compute(&per_worker_secs);
        let param_bytes = self.backend.info().param_bytes();
        self.clock.charge_allreduce(&self.cfg.comm, n, param_bytes, &mut self.fault_rng);
        // shared optimizer state lives in worker 0's optimizer
        self.workers[0].opt.step(&mut self.global, &mean_grad, lr);
        self.local_step += 1;
        anyhow::ensure!(tensor::all_finite(&self.global), "global params diverged");
        Ok(())
    }

    /// Persistent per-rank payload buffers: (re)built whenever the
    /// round's (fleet size, format, dimension) disagrees with what the
    /// buffers hold — the first round, or a config change across a
    /// checkpoint resume — instead of asserting. For the `topk` wire
    /// the buffers also carry each rank's residual momentum, so a
    /// rebuild zeroes that state; [`Self::load_checkpoint`] rebuilds
    /// first and restores the checkpointed residuals on top.
    fn ensure_payload_buffers(&mut self) {
        let n = self.cfg.n_workers;
        let p = self.global.len();
        if self.payloads.len() != n
            || self.payloads.iter().any(|pl| pl.format() != self.wire || pl.len() != p)
        {
            self.payloads =
                (0..n).map(|_| WirePayload::with_layout(self.wire, &self.layout)).collect();
            // Pin the framed-encoding contract at every rebuild in
            // debug builds: the frame length a rank would put on the
            // simulated wire is exactly the byte count the clock bills.
            #[cfg(debug_assertions)]
            {
                let mut frame = Vec::new();
                for pl in &self.payloads {
                    pl.encode_into(&mut frame);
                    debug_assert_eq!(frame.len() as u64, pl.wire_bytes());
                }
            }
        }
    }

    /// Reputation scoring for one applied round (only under
    /// [`crate::comm::FaultPlan::quarantine`]). Two per-survivor
    /// signals, no fault-stream or trainer-RNG draws:
    ///
    /// - **norm z-score** — the rank's decoded diff norm against the
    ///   survivor median, spread-normalized by the MAD. Catches what
    ///   scale can't hide: inflators and fixed-point colluders.
    /// - **sign agreement** — the fraction of the rank's transmitted
    ///   coordinates whose diff sign matches the applied update.
    ///   Catches what direction can't hide: sign-flippers (the 1-bit
    ///   wire scores this through [`crate::dist::PackedVotes::agreement`];
    ///   its votes are unit-norm, so the z-score is inert there).
    ///
    /// Reputation halves toward each verdict; crossing the quarantine
    /// line freezes the rank with doubling backoff, capped below n/2
    /// frozen ranks so the fleet keeps a clean majority slot.
    fn score_survivors(&mut self, start: &[f32], survivor_ranks: &[usize]) {
        let n = self.cfg.n_workers;
        let p = start.len();
        // the consensus diff the server just applied (start − global)
        let applied: Vec<f32> = (0..p).map(|i| start[i] - self.global[i]).collect();
        let mut norms = Vec::with_capacity(survivor_ranks.len());
        let mut agrees = Vec::with_capacity(survivor_ranks.len());
        let mut end = vec![0.0f32; p];
        for &w in survivor_ranks {
            if let Some(votes) = self.payloads[w].as_packed_signs() {
                norms.push(0.0);
                agrees.push(votes.agreement(&applied));
                continue;
            }
            let one = std::slice::from_ref(&self.payloads[w]);
            if WirePayload::aggregate_end_into(AggPolicy::Mean, one, start, &mut end).is_err() {
                // the payload already survived check_finite; an
                // undecodable one here scores neutral instead of
                // crashing the run
                norms.push(0.0);
                agrees.push(1.0);
                continue;
            }
            let mut norm = 0.0f64;
            let (mut hits, mut spoke) = (0u64, 0u64);
            for i in 0..p {
                let d = start[i] as f64 - end[i] as f64;
                norm += d * d;
                if d != 0.0 {
                    spoke += 1;
                    if (d > 0.0) == (applied[i] as f64 > 0.0) {
                        hits += 1;
                    }
                }
            }
            norms.push(norm.sqrt());
            agrees.push(if spoke == 0 { 1.0 } else { hits as f64 / spoke as f64 });
        }
        // robust center/spread of the survivor norms — valid while the
        // adversaries stay a minority of the survivors
        let med = median(&norms);
        let mad = median(&norms.iter().map(|&x| (x - med).abs()).collect::<Vec<_>>());
        for (k, &w) in survivor_ranks.iter().enumerate() {
            let z = (norms[k] - med).abs() / (1.4826 * mad + 1e-9);
            let good = z <= Self::Z_THRESH && agrees[k] >= Self::AGREE_THRESH;
            self.rep[w] = 0.5 * self.rep[w] + if good { 0.5 } else { 0.0 };
            if self.rep[w] >= Self::REP_QUARANTINE {
                continue;
            }
            // freeze the rank — unless half the fleet is already out
            // (liveness: the membership guard needs a clean rank left)
            let frozen = self.quarantine_left.iter().filter(|&&q| q > 0).count();
            if frozen < n / 2 {
                self.backoff[w] = (self.backoff[w] * 2).max(Self::QUARANTINE_BASE);
                self.quarantine_left[w] = self.backoff[w];
                self.faults.quarantined_ranks += 1;
            }
        }
    }

    /// Mean validation loss over the configured eval batches.
    ///
    /// The batches fan out across the persistent pool (one read-only
    /// job per batch, [`pool::run_indexed`]); per-batch losses are
    /// gathered by index and summed in batch order in f64 — exactly the
    /// serial [`StepBackend::eval_loss_many`] arithmetic, so the pooled
    /// pass is bitwise-identical to the serial reference, which
    /// `cfg.sequential_workers` keeps reachable (and which also serves
    /// the degenerate single-batch / single-core cases).
    pub fn evaluate(&mut self) -> Result<f64> {
        if self.cfg.sequential_workers
            || self.val_batches.len() <= 1
            || pool::global().helpers() == 0
        {
            return self.backend.eval_loss_many(&self.global, &self.val_batches);
        }
        let backend = &self.backend;
        let global = &self.global;
        let losses: Vec<Result<f32>> =
            pool::run_indexed(&self.val_batches, move |_, batch| backend.eval_loss(global, batch));
        let mut acc = 0.0f64;
        for loss in losses {
            acc += loss? as f64;
        }
        Ok(acc / self.val_batches.len() as f64)
    }

    // ---- checkpointing ----

    /// Supervisor state as exact f32 words: `[n]` then, per rank, the
    /// f64 reputation's bit pattern, the quarantine rounds left, and
    /// the backoff — each u64 spread over four 16-bit limbs (an f32
    /// holds 16-bit integers exactly, so the round trip is lossless
    /// and resume is bit-identical mid-quarantine).
    fn supervisor_to_f32_words(&self) -> Vec<f32> {
        let n = self.cfg.n_workers;
        let mut words = Vec::with_capacity(1 + 12 * n);
        words.push(n as f32);
        let push_u64 = |words: &mut Vec<f32>, x: u64| {
            for k in 0..4 {
                words.push(((x >> (16 * k)) & 0xFFFF) as f32);
            }
        };
        for w in 0..n {
            push_u64(&mut words, self.rep[w].to_bits());
            push_u64(&mut words, self.quarantine_left[w]);
            push_u64(&mut words, self.backoff[w]);
        }
        words
    }

    /// Inverse of [`Self::supervisor_to_f32_words`]; errors loudly on
    /// a length or fleet-size mismatch instead of guessing.
    fn load_supervisor_f32_words(&mut self, words: &[f32]) -> Result<()> {
        let n = self.cfg.n_workers;
        anyhow::ensure!(
            words.len() == 1 + 12 * n && words[0] as usize == n,
            "trainer.supervisor holds {} words (fleet of {} needs {})",
            words.len(),
            n,
            1 + 12 * n
        );
        let read_u64 = |limbs: &[f32]| -> u64 {
            limbs
                .iter()
                .enumerate()
                .fold(0u64, |acc, (k, &x)| acc | (((x as u64) & 0xFFFF) << (16 * k)))
        };
        for w in 0..n {
            let base = 1 + 12 * w;
            self.rep[w] = f64::from_bits(read_u64(&words[base..base + 4]));
            self.quarantine_left[w] = read_u64(&words[base + 4..base + 8]);
            self.backoff[w] = read_u64(&words[base + 8..base + 12]);
        }
        Ok(())
    }

    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let mut ck = Checkpoint::new(&self.cfg.tag, self.round);
        ck.add("global", &self.global);
        // local_step as four exact 16-bit limbs — an f32 only holds
        // integers up to 2^24 exactly, and long runs exceed that
        let step_limbs: Vec<f32> =
            (0..4).map(|k| ((self.local_step >> (16 * k)) & 0xFFFF) as f32).collect();
        ck.add("meta.local_step64", &step_limbs);
        ck.add("meta.local_step", &[self.local_step as f32]); // legacy readers
        for (i, buf) in self.outer.state().iter().enumerate() {
            ck.add(&format!("outer.{i}"), buf);
        }
        for w in &self.workers {
            for (i, buf) in w.opt.state().iter().enumerate() {
                ck.add(&format!("worker{}.opt{i}", w.id), buf);
            }
        }
        // worker-side residual momentum for the sparse topk wire: the
        // persistent payload buffers double as that state, and a
        // resumed run must hold exactly the untransmitted mass the
        // interrupted one did.
        for (w, pl) in self.payloads.iter().enumerate() {
            if let Some(r) = pl.residual() {
                ck.add(&format!("worker{w}.topk_residual"), r);
            }
        }
        // RNG streams: with these restored, a resumed run replays the
        // uninterrupted one bit-for-bit (workers resample identically,
        // randomized sign votes and straggler draws continue in place).
        for w in &self.workers {
            ck.add(&format!("worker{}.rng", w.id), &w.rng.to_f32_words());
        }
        ck.add("trainer.rng", &self.rng.to_f32_words());
        // the fault/jitter stream and counters: restored, a resumed
        // faulty run replays its churn/drop/corrupt/straggler draws in
        // place and keeps counting where it left off.
        ck.add("trainer.fault_rng", &self.fault_rng.to_f32_words());
        ck.add("trainer.faults", &self.faults.to_f32_words());
        // the reputation/quarantine supervisor: per-rank reputation,
        // rounds left, and backoff — a resumed faulty run must keep
        // scoring mid-quarantine exactly where the interrupted one
        // stood.
        ck.add("trainer.supervisor", &self.supervisor_to_f32_words());
        // simulated clock: a resumed run continues the time axis
        // (compute/comm/straggler seconds, comm rounds, wire bytes)
        // instead of restarting it at zero.
        ck.add("trainer.clock", &self.clock.to_f32_words());
        ck.save(path)
    }

    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let ck = Checkpoint::load(path)?;
        let global = ck.get("global")?;
        anyhow::ensure!(
            global.len() == self.global.len(),
            "checkpoint has {} params, model needs {}",
            global.len(),
            self.global.len()
        );
        self.global.copy_from_slice(global);
        self.local_step = if let Ok(limbs) = ck.get("meta.local_step64") {
            limbs
                .iter()
                .enumerate()
                .fold(0u64, |acc, (k, &x)| acc | ((x as u64) << (16 * k)))
        } else {
            // pre-limb checkpoints: exact only below 2^24 steps
            ck.get("meta.local_step")?[0] as u64
        };
        self.round = ck.round;
        let outer_bufs = ck.with_prefix("outer.");
        if !outer_bufs.is_empty() {
            self.outer.load_state(&outer_bufs);
        }
        for w in &mut self.workers {
            let bufs = ck.with_prefix(&format!("worker{}.opt", w.id));
            if !bufs.is_empty() {
                w.opt.load_state(&bufs);
            }
        }
        // RNG streams are present in newer checkpoints; older ones
        // still load (workers then resample from their fresh streams).
        if let Ok(words) = ck.get("trainer.rng") {
            self.rng = Rng::from_f32_words(words)
                .ok_or_else(|| anyhow::anyhow!("corrupt trainer.rng buffer"))?;
        }
        for w in &mut self.workers {
            if let Ok(words) = ck.get(&format!("worker{}.rng", w.id)) {
                w.rng = Rng::from_f32_words(words).ok_or_else(|| {
                    anyhow::anyhow!("corrupt worker{}.rng buffer", w.id)
                })?;
            }
        }
        // fault stream + counters (newer checkpoints); older ones load
        // with a fresh stream and zeroed counters.
        if let Ok(words) = ck.get("trainer.fault_rng") {
            self.fault_rng = Rng::from_f32_words(words)
                .ok_or_else(|| anyhow::anyhow!("corrupt trainer.fault_rng buffer"))?;
        }
        if let Ok(words) = ck.get("trainer.faults") {
            self.faults = FaultStats::from_f32_words(words)
                .map_err(|e| anyhow::anyhow!("trainer.faults: {e}"))?;
        }
        // supervisor state (newer checkpoints); older ones load with
        // full reputation and no quarantine in flight.
        if let Ok(words) = ck.get("trainer.supervisor") {
            self.load_supervisor_f32_words(words)?;
        }
        // simulated clock (newer checkpoints); pre-clock checkpoints
        // still load and restart the time axis at zero.
        if let Ok(words) = ck.get("trainer.clock") {
            self.clock = SimClock::from_f32_words(words)
                .ok_or_else(|| anyhow::anyhow!("corrupt trainer.clock buffer"))?;
        }
        // topk residual momentum: rebuild the payload buffers for the
        // configured wire (fresh zeros), then restore the checkpointed
        // residuals on top. Non-topk buffers have no residual and skip
        // the loop; checkpoints without the keys (older, or written by
        // a different wire) leave the fresh zeros in place.
        self.ensure_payload_buffers();
        for (w, pl) in self.payloads.iter_mut().enumerate() {
            let Some(r) = pl.residual_mut() else { break };
            if let Ok(words) = ck.get(&format!("worker{w}.topk_residual")) {
                anyhow::ensure!(
                    words.len() == r.len(),
                    "worker{w}.topk_residual holds {} of {} coordinates",
                    words.len(),
                    r.len()
                );
                r.copy_from_slice(words);
            }
        }
        Ok(())
    }
}
