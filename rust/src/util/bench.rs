//! Micro-benchmark harness substrate (criterion is unavailable offline).
//!
//! `cargo bench` targets declare `harness = false` and drive this: each
//! benchmark warms up, then runs timed batches until a wall-clock budget
//! is spent, reporting mean / p50 / p95 per-iteration times and derived
//! throughput.  Deliberately simple, but the statistics are honest:
//! batch-level medians over many batches, not a single hot loop.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// Optional bytes processed per iteration (enables GB/s reporting).
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn throughput_gbs(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.mean_ns)
    }

    pub fn report(&self) -> String {
        let human = |ns: f64| -> String {
            if ns < 1e3 {
                format!("{ns:.1} ns")
            } else if ns < 1e6 {
                format!("{:.2} µs", ns / 1e3)
            } else if ns < 1e9 {
                format!("{:.2} ms", ns / 1e6)
            } else {
                format!("{:.3} s", ns / 1e9)
            }
        };
        let mut line = format!(
            "{:<44} {:>12}/iter  p50 {:>10}  p95 {:>10}  ({} iters)",
            self.name,
            human(self.mean_ns),
            human(self.p50_ns),
            human(self.p95_ns),
            self.iters
        );
        if let Some(gbs) = self.throughput_gbs() {
            line.push_str(&format!("  {gbs:.2} GB/s"));
        }
        line
    }
}

pub struct Bencher {
    budget: Duration,
    warmup: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new(Duration::from_millis(800), Duration::from_millis(120))
    }
}

impl Bencher {
    pub fn new(budget: Duration, warmup: Duration) -> Self {
        Bencher { budget, warmup, results: Vec::new() }
    }

    /// Quick harness for CI-ish runs (shorter budget).
    pub fn quick() -> Self {
        Bencher::new(Duration::from_millis(250), Duration::from_millis(50))
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.bench_with_bytes(name, None, f)
    }

    /// `bytes` is the data volume touched per iteration, for GB/s output.
    pub fn bench_with_bytes<F: FnMut()>(
        &mut self,
        name: &str,
        bytes: Option<u64>,
        mut f: F,
    ) -> &BenchResult {
        // Warmup + batch-size calibration.
        let w0 = Instant::now();
        let mut warm_iters: u64 = 0;
        while w0.elapsed() < self.warmup || warm_iters == 0 {
            f();
            warm_iters += 1;
        }
        let est_ns = (self.warmup.as_nanos() as f64 / warm_iters as f64).max(1.0);
        // Aim for ~1ms per batch so Instant overhead is negligible.
        let batch = ((1e6 / est_ns).ceil() as u64).clamp(1, 1 << 20);

        let mut samples: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.budget || samples.is_empty() {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
        }
        samples.sort_by(f64::total_cmp); // identical order: samples are finite and positive
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let pct = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
        let result = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            bytes_per_iter: bytes,
        };
        println!("{}", result.report());
        self.results.push(result);
        let Some(latest) = self.results.last() else {
            unreachable!("a result was just pushed")
        };
        latest
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Prevent the optimizer from discarding a computed value (std::hint
/// black_box is stable but we keep a volatile-read fallback semantics).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::new(Duration::from_millis(30), Duration::from_millis(5));
        let mut acc = 0u64;
        let r = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 100);
    }

    #[test]
    fn percentiles_ordered_and_throughput() {
        let mut b = Bencher::new(Duration::from_millis(30), Duration::from_millis(5));
        let data = vec![1.0f32; 4096];
        let r = b
            .bench_with_bytes("sum4k", Some(4096 * 4), || {
                black_box(data.iter().sum::<f32>());
            })
            .clone();
        assert!(r.p50_ns <= r.p95_ns * 1.0001);
        assert!(r.throughput_gbs().unwrap() > 0.0);
    }
}
