//! Minimal CLI argument substrate (no clap offline).
//!
//! `Args` wraps `--key value` / `--key=value` flags plus positionals, with
//! typed getters that accumulate a usage error instead of panicking.  The
//! launcher (`main.rs`) builds its subcommands on top of this.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    used: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse a raw argv tail (everything after the subcommand).
    ///
    /// `--key value` is ambiguous with `--boolean positional`; callers
    /// that use boolean flags pass their names in `known_bools` (the
    /// registry clap would otherwise provide).
    pub fn parse_with_bools<I: IntoIterator<Item = String>>(
        argv: I,
        known_bools: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if known_bools.contains(&flag) {
                    out.bools.push(flag.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(flag.to_string(), v);
                } else {
                    out.bools.push(flag.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        Self::parse_with_bools(argv, &[])
    }

    pub fn has(&self, key: &str) -> bool {
        self.used.borrow_mut().push(key.to_string());
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.used.borrow_mut().push(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got `{v}`")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected integer, got `{v}`")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected float, got `{v}`")),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32, String> {
        self.f64_or(key, default as f64).map(|v| v as f32)
    }

    /// Flags that were provided but never consumed — typo detection.
    pub fn unknown_flags(&self) -> Vec<String> {
        let used = self.used.borrow();
        self.flags
            .keys()
            .chain(self.bools.iter())
            .filter(|k| !used.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_with_bools(s.split_whitespace().map(String::from), &["verbose"]).unwrap()
    }

    #[test]
    fn mixed_styles() {
        let a = args("train --preset medium --tau=24 --verbose pos1");
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.get("preset"), Some("medium"));
        assert_eq!(a.usize_or("tau", 1).unwrap(), 24);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_errors() {
        let a = args("--tau twelve");
        assert!(a.usize_or("tau", 1).is_err());
        assert_eq!(a.f64_or("lr", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = args("--mu=-0.5");
        assert_eq!(a.f64_or("mu", 0.0).unwrap(), -0.5);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = args("--known 1 --misspelled 2");
        let _ = a.get("known");
        assert_eq!(a.unknown_flags(), vec!["misspelled".to_string()]);
    }
}
