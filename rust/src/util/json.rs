//! Minimal JSON substrate: parser + writer.
//!
//! The offline registry carries no serde/serde_json, so the runtime's
//! `artifacts/manifest.json` loader and the experiment logs use this
//! hand-rolled implementation.  It supports the full JSON grammar
//! (objects, arrays, strings with escapes incl. \uXXXX, numbers, bools,
//! null); object key order is preserved for stable round-trips.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap gives deterministic iteration; original key order is not
    /// semantically meaningful for our uses (manifest, logs).
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors (None on type/shape mismatch) ----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi""#).unwrap().as_str(), Some("hi"));
    }

    #[test]
    fn parses_nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(), Some("c"));
        assert_eq!(v.get("d").unwrap().get("e").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn escapes_roundtrip() {
        let original = Json::Str("line\n\"quote\"\tταβ\u{1F600}".to_string());
        let text = original.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
        assert_eq!(Json::parse(r#""😀""#).unwrap().as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01a").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let text = r#"{"n":1,"s":"x","a":[true,null,2.5]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"version":1,"presets":{"nano":{"param_count":366432,
            "config":{"vocab":256,"seq":64},"artifacts":{"train":{"file":"nano_train.hlo.txt"}}}}}"#;
        let v = Json::parse(text).unwrap();
        let nano = v.get("presets").unwrap().get("nano").unwrap();
        assert_eq!(nano.get("param_count").unwrap().as_usize(), Some(366432));
    }
}
