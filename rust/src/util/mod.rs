//! In-tree substrates replacing the unavailable crates-io stack
//! (see Cargo.toml note): PRNG, JSON, TOML-subset, CLI args, bench harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod toml;
