//! Deterministic PRNG substrate (no external crates available offline).
//!
//! `Rng` is xoshiro256** seeded through SplitMix64 — the standard pairing:
//! SplitMix64 whitens arbitrary seeds, xoshiro256** provides the stream.
//! Every stochastic component in the system (data sharding, randomized
//! sign operators, straggler jitter, synthetic corpus) draws from an `Rng`
//! derived via [`Rng::substream`], so runs are exactly reproducible from a
//! single root seed and workers get provably disjoint streams.

/// xoshiro256** with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream keyed by `(domain, index)`.
    ///
    /// Used to hand each worker / component its own generator: streams for
    /// different keys are seeded through SplitMix64 of disjoint inputs and
    /// are independent for all practical purposes.
    pub fn substream(&self, domain: &str, index: u64) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a over the domain tag
        for b in domain.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(self.s[0] ^ h.rotate_left(17) ^ index.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Unbiased via Lemire-style rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            let wide = (r as u128) * (n as u128);
            let (hi, lo) = ((wide >> 64) as u64, wide as u64);
            if lo >= threshold {
                return hi;
            }
        }
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Log-normal sample (used for straggler delay multipliers).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto(α) sample with minimum 1 via inverse transform: heavy
    /// tails for fault-injected straggler stalls (α ≤ 1 has no mean).
    pub fn pareto(&mut self, alpha: f64) -> f64 {
        // 1 - f64() is in (0, 1], so the power is finite
        (1.0 - self.f64()).powf(-1.0 / alpha)
    }

    /// Fill a slice with N(0, std) f32 noise.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Number of f32 words [`Rng::to_f32_words`] produces.
    pub const F32_WORDS: usize = 21;

    /// Serialize the full generator state to f32 words for the
    /// checkpoint container (which stores flat f32 buffers): each u64
    /// state word becomes four 16-bit limbs — exactly representable in
    /// f32 — followed by the cached Box-Muller spare (presence flag
    /// plus the f64's bits as four more limbs). Checkpointing the RNG
    /// streams is what makes a resumed run bit-identical to the
    /// uninterrupted one.
    pub fn to_f32_words(&self) -> Vec<f32> {
        fn push_u64(out: &mut Vec<f32>, w: u64) {
            for k in 0..4 {
                out.push(((w >> (16 * k)) & 0xFFFF) as f32);
            }
        }
        let mut out = Vec::with_capacity(Self::F32_WORDS);
        for &w in &self.s {
            push_u64(&mut out, w);
        }
        out.push(if self.gauss_spare.is_some() { 1.0 } else { 0.0 });
        push_u64(&mut out, self.gauss_spare.map_or(0, f64::to_bits));
        out
    }

    /// Rebuild a generator from [`Rng::to_f32_words`] output; `None` on
    /// a malformed buffer (wrong length or non-limb values).
    pub fn from_f32_words(words: &[f32]) -> Option<Rng> {
        fn read_u64(words: &[f32]) -> Option<u64> {
            let mut w = 0u64;
            for (k, &x) in words.iter().enumerate() {
                if !(0.0..65536.0).contains(&x) || x.fract() != 0.0 {
                    return None;
                }
                w |= (x as u64) << (16 * k);
            }
            Some(w)
        }
        if words.len() != Self::F32_WORDS {
            return None;
        }
        let s = [
            read_u64(&words[0..4])?,
            read_u64(&words[4..8])?,
            read_u64(&words[8..12])?,
            read_u64(&words[12..16])?,
        ];
        let gauss_spare = if words[16] == 1.0 {
            Some(f64::from_bits(read_u64(&words[17..21])?))
        } else if words[16] == 0.0 {
            None
        } else {
            return None;
        };
        Some(Rng { s, gauss_spare })
    }
}

/// Zipf(s) sampler over ranks 0..n by inverse-CDF on a precomputed table.
///
/// Heavy-tailed token frequencies are the single most important statistical
/// property of natural text for LM-loss dynamics, so the synthetic corpus
/// (data/corpus.rs) draws word ranks from this.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = cdf[n - 1]; // n > 0 is asserted; cdf has exactly n entries
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn substreams_are_disjoint_and_stable() {
        let root = Rng::new(7);
        let mut w0 = root.substream("worker", 0);
        let mut w0b = root.substream("worker", 0);
        let mut w1 = root.substream("worker", 1);
        let mut d0 = root.substream("data", 0);
        let a: Vec<u64> = (0..4).map(|_| w0.next_u64()).collect();
        assert_eq!(a, (0..4).map(|_| w0b.next_u64()).collect::<Vec<_>>());
        assert_ne!(a, (0..4).map(|_| w1.next_u64()).collect::<Vec<_>>());
        assert_ne!(a, (0..4).map(|_| d0.next_u64()).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut rng = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 20_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_over_small_range() {
        let mut rng = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 / 10_000.0 - 1.0).abs() < 0.06, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn pareto_is_heavy_tailed_above_one() {
        let mut rng = Rng::new(21);
        let n = 20_000;
        // alpha = 3: finite variance, E[X] = 3/2 — the sample mean pins
        // the inverse transform
        let mean = (0..n).map(|_| rng.pareto(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 1.5).abs() < 0.05, "mean {mean}");
        // alpha = 1.5: every draw >= 1 and the tail produces extremes
        // far beyond the median 2^(1/1.5) ~= 1.6
        let mut max = 0.0f64;
        for _ in 0..n {
            let x = rng.pareto(1.5);
            assert!(x >= 1.0 && x.is_finite());
            max = max.max(x);
        }
        assert!(max > 50.0, "heavy tail should show extremes, max {max}");
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let zipf = Zipf::new(50, 1.1);
        let mut rng = Rng::new(5);
        let mut counts = vec![0usize; 50];
        for _ in 0..100_000 {
            counts[zipf.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[4] && counts[4] > counts[20]);
        // rank-0 frequency for s=1.1, n=50 is ~22%.
        assert!((counts[0] as f64 / 100_000.0 - 0.22).abs() < 0.05);
    }

    #[test]
    fn state_words_roundtrip_bitwise() {
        let mut rng = Rng::new(77);
        for _ in 0..13 {
            rng.next_u64();
        }
        rng.normal(); // populate the Box-Muller spare
        let words = rng.to_f32_words();
        assert_eq!(words.len(), Rng::F32_WORDS);
        let mut orig = rng.clone();
        let mut back = Rng::from_f32_words(&words).unwrap();
        // spare must replay first, then the streams stay in lockstep
        assert_eq!(orig.normal().to_bits(), back.normal().to_bits());
        for _ in 0..32 {
            assert_eq!(orig.next_u64(), back.next_u64());
        }
    }

    #[test]
    fn state_words_reject_garbage() {
        let rng = Rng::new(5);
        let words = rng.to_f32_words();
        assert!(Rng::from_f32_words(&words[1..]).is_none(), "wrong length");
        let mut bad = words.clone();
        bad[3] = 0.5; // not a 16-bit integer limb
        assert!(Rng::from_f32_words(&bad).is_none());
        let mut bad_flag = words;
        bad_flag[16] = 2.0;
        assert!(Rng::from_f32_words(&bad_flag).is_none());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
