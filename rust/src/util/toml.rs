//! Minimal TOML-subset parser for run configuration files.
//!
//! Supports the subset real training configs need (and nothing more):
//! `[section]` / `[a.b]` tables, `key = value` with strings, integers,
//! floats, booleans, and flat arrays of those; `#` comments; blank lines.
//! Values land in the same [`Json`] tree the rest of the system uses, so
//! `config/` has a single typed-accessor path for both formats.

use std::collections::BTreeMap;

use super::json::Json;

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

pub fn parse(text: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    let mut path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };

        if let Some(section) = line.strip_prefix('[') {
            let section = section
                .strip_suffix(']')
                .ok_or_else(|| err("unterminated section header"))?
                .trim();
            if section.is_empty() {
                return Err(err("empty section name"));
            }
            path = section.split('.').map(|s| s.trim().to_string()).collect();
            // materialize the table so empty sections still exist
            ensure_table(&mut root, &path).map_err(|m| err(&m))?;
            continue;
        }

        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| err("expected `key = value`"))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(err("empty key"));
        }
        let value = parse_value(value.trim()).map_err(|m| err(&m))?;
        let table = ensure_table(&mut root, &path).map_err(|m| err(&m))?;
        if table.insert(key.to_string(), value).is_some() {
            return Err(err(&format!("duplicate key `{key}`")));
        }
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string must not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Json>, String> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            _ => return Err(format!("`{part}` is both a value and a table")),
        };
    }
    Ok(cur)
}

fn parse_value(s: &str) -> Result<Json, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return unescape(inner);
    }
    if s == "true" {
        return Ok(Json::Bool(true));
    }
    if s == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Json::Arr(vec![]));
        }
        return split_top_level(inner)
            .into_iter()
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>, _>>()
            .map(Json::Arr);
    }
    // number (TOML allows underscores)
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("cannot parse value `{s}`"))
}

/// Split array items on commas that are not inside quotes.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

fn unescape(s: &str) -> Result<Json, String> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            other => return Err(format!("unknown escape \\{other:?}")),
        }
    }
    Ok(Json::Str(out))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_run_config() {
        let cfg = parse(
            r#"
# run config
preset = "medium"
workers = 8
tau = 12

[outer]
algo = "sign_momentum"   # Algorithm 1
beta1 = 0.95
beta2 = 0.98
global_lr = 1.0
weight_decay = 0.1

[base]
algo = "adamw"
betas = [0.9, 0.95]

[comm]
preset = "ethernet"
"#,
        )
        .unwrap();
        assert_eq!(cfg.get("preset").unwrap().as_str(), Some("medium"));
        assert_eq!(cfg.get("tau").unwrap().as_usize(), Some(12));
        let outer = cfg.get("outer").unwrap();
        assert_eq!(outer.get("beta2").unwrap().as_f64(), Some(0.98));
        let betas = cfg.get("base").unwrap().get("betas").unwrap().as_arr().unwrap();
        assert_eq!(betas[1].as_f64(), Some(0.95));
    }

    #[test]
    fn nested_sections() {
        let cfg = parse("[a.b]\nx = 1\n[a.c]\ny = 2\n").unwrap();
        assert_eq!(cfg.get("a").unwrap().get("b").unwrap().get("x").unwrap().as_usize(), Some(1));
        assert_eq!(cfg.get("a").unwrap().get("c").unwrap().get("y").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn strings_with_hash_and_escapes() {
        let cfg = parse(r#"s = "a # not comment\n""#).unwrap();
        assert_eq!(cfg.get("s").unwrap().as_str(), Some("a # not comment\n"));
    }

    #[test]
    fn numbers_with_underscores_and_floats() {
        let cfg = parse("big = 100_000\nlr = 5e-4\nneg = -3\n").unwrap();
        assert_eq!(cfg.get("big").unwrap().as_usize(), Some(100_000));
        assert_eq!(cfg.get("lr").unwrap().as_f64(), Some(5e-4));
        assert_eq!(cfg.get("neg").unwrap().as_f64(), Some(-3.0));
    }

    #[test]
    fn error_reporting_includes_line() {
        let e = parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(parse("[x\n").is_err());
        assert!(parse("a = 1\na = 2\n").is_err(), "duplicate keys rejected");
    }

    #[test]
    fn empty_and_comment_only_lines() {
        let cfg = parse("\n\n# only comments\n\n").unwrap();
        assert_eq!(cfg, Json::Obj(Default::default()));
    }
}
