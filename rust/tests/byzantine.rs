//! Byzantine ranks end to end on the pure-Rust [`NativeBundle`]
//! backend: adversary injection, the robust aggregation policies, the
//! reputation/quarantine supervisor, and the pinned held-round and
//! freeze semantics.
//!
//! The contracts pinned here:
//!
//! 1. **Breakdown behavior** — a colluding minority poisons the
//!    undefended mean while the trimmed/median policies and the MV
//!    tally hold their loss next to the clean baseline.
//! 2. **Supervisor** — the quarantine supervisor finds the liar from
//!    the update statistics alone, freezes it with churn-absence
//!    semantics (worker RNG and base-optimizer state untouched), and
//!    re-admits it on probation.
//! 3. **Held rounds** — a no-quorum round advances the LR schedule and
//!    the clock but consumes no trainer RNG and leaves the outer
//!    optimizer state and the global parameters untouched.
//! 4. **Determinism** — the adversary set is drawn once per run and a
//!    resume with an active quarantine replays bit-for-bit.

use std::sync::Arc;

use dsm::comm::Attack;
use dsm::config::RunConfig;
use dsm::dist::AggPolicy;
use dsm::outer::OuterConfig;
use dsm::runtime::NativeBundle;
use dsm::train::checkpoint::Checkpoint;
use dsm::train::Trainer;

const PRESET: &str = "native";

/// ln(256), the byte LM's uniform loss — the "did not diverge" anchor.
fn uniform() -> f64 {
    (256f64).ln()
}

fn backend() -> Arc<NativeBundle> {
    Arc::new(NativeBundle::new(PRESET, 2, 24, 8))
}

/// Plain parameter averaging: the undefended mean the attacks are
/// built to poison. (The paper-default sign-momentum outer bounds
/// every coordinate by the LR, which would hide the contrast between
/// the undefended and the defended rows.)
fn avg_cfg(tag: &str) -> RunConfig {
    let mut cfg = RunConfig::paper_default(PRESET);
    cfg.rounds = 4;
    cfg.tau = 3;
    cfg.n_workers = 4;
    cfg.corpus_bytes = 1 << 16;
    cfg.eval_every = 0;
    cfg.eval_batches = 2;
    cfg.comm = dsm::comm::CommModel::preset("ethernet").unwrap();
    cfg.outer = OuterConfig::LocalAvg;
    cfg.tag = tag.to_string();
    cfg
}

fn mv_cfg(tag: &str) -> RunConfig {
    let mut cfg = avg_cfg(tag);
    cfg.outer = OuterConfig::MvSignSgd { eta: 1e-3, beta: 0.9, alpha: 0.1, bound: 50.0 };
    cfg
}

/// Final validation loss, with a mid-run finiteness trip mapped to
/// +inf — for a poisoned mean, divergence IS the expected outcome.
fn run_val(cfg: RunConfig) -> f64 {
    let mut t = Trainer::with_backend(cfg, backend()).unwrap();
    match t.run() {
        Ok(res) => res.final_val,
        Err(_) => f64::INFINITY,
    }
}

#[test]
fn the_adversary_set_is_drawn_once_and_reproducible() {
    let mut cfg = avg_cfg("byz-draw");
    cfg.faults.byzantine_frac = 0.5; // ⌊0.5·4⌋ = 2 adversaries
    cfg.faults.attack = Attack::SignFlip;
    let t1 = Trainer::with_backend(cfg.clone(), backend()).unwrap();
    let t2 = Trainer::with_backend(cfg, backend()).unwrap();
    assert_eq!(t1.adversaries(), t2.adversaries(), "membership must be a pure seed function");
    assert_eq!(t1.adversaries().iter().filter(|&&b| b).count(), 2);
}

#[test]
fn collusion_poisons_the_mean_and_the_robust_policies_recover() {
    let clean = run_val(avg_cfg("byz-clean"));
    assert!(clean.is_finite());

    let mut mean = avg_cfg("byz-mean-collude");
    mean.faults.byzantine_frac = 0.25; // one colluder in the fleet of 4
    mean.faults.attack = Attack::ColludeFixed;
    let mean_val = run_val(mean);
    // the colluder shifts every coordinate by frac per round; the
    // undefended mean either trips the finiteness guard or lands far
    // from the clean baseline
    assert!(
        !mean_val.is_finite() || mean_val > clean + 0.4,
        "the undefended mean shrugged off the collusion: {mean_val} vs clean {clean}"
    );

    for (name, agg) in [("trimmed", AggPolicy::Trimmed), ("median", AggPolicy::Median)] {
        let mut cfg = avg_cfg(&format!("byz-{name}-collude"));
        cfg.agg = agg;
        cfg.faults.byzantine_frac = 0.25;
        cfg.faults.attack = Attack::ColludeFixed;
        let val = run_val(cfg);
        assert!(val.is_finite(), "{name} diverged under collusion");
        assert!(
            (val - clean).abs() < 0.35,
            "{name} drifted from the clean baseline: {val} vs {clean}"
        );
    }
}

#[test]
fn mv_tally_holds_its_loss_under_a_sign_flip_minority() {
    let clean = run_val(mv_cfg("byz-mv-clean"));
    let mut cfg = mv_cfg("byz-mv-flip");
    cfg.faults.byzantine_frac = 0.25;
    cfg.faults.attack = Attack::SignFlip;
    let mut t = Trainer::with_backend(cfg, backend()).unwrap();
    let res = t.run().unwrap();
    // one flipped vote out of four arrives every round — and survives
    // (a Byzantine rank lies, it does not crash the round)
    assert_eq!(res.faults.byzantine_rounds_survived, 4);
    assert_eq!(res.faults.rejected_payloads, 0);
    assert!(res.final_val.is_finite());
    assert!(
        (res.final_val - clean).abs() < 0.5,
        "a 1-in-4 sign-flipper moved the tally too far: {} vs {}",
        res.final_val,
        clean
    );
}

#[test]
fn the_supervisor_quarantines_the_inflator_and_readmits_on_probation() {
    let mut cfg = avg_cfg("byz-quarantine");
    cfg.rounds = 8;
    // a fleet of 8 with one inflator: the survivor-norm MAD needs a
    // handful of honest samples to be a stable spread estimate
    cfg.n_workers = 8;
    cfg.faults.byzantine_frac = 0.125;
    cfg.faults.attack = Attack::ScaleInflate;
    cfg.faults.quarantine = true;
    let mut t = Trainer::with_backend(cfg, backend()).unwrap();
    let adv = t.adversaries().iter().position(|&b| b).unwrap();
    let res = t.run().unwrap();
    // reputation decays 1.0 → 0.5 → 0.25 over the first two poisoned
    // rounds, so the freeze lands by round 2 and, with an 8-round run
    // and a 4-round base backoff, the probation window reopens
    assert!(res.faults.quarantined_ranks >= 1, "the supervisor never fired");
    assert!(res.faults.readmissions >= 1, "the backoff never expired");
    let rep = t.reputations();
    for w in 0..8 {
        if w != adv {
            assert!(
                rep[adv] < rep[w],
                "the liar (rank {adv}, rep {}) must end below honest rank {w} (rep {})",
                rep[adv],
                rep[w]
            );
        }
    }
    assert!(res.final_val.is_finite());
    assert!(res.final_val < uniform() + 0.5, "quarantined fleet diverged: {}", res.final_val);
}

#[test]
fn a_quarantined_rank_is_frozen_exactly_like_a_churn_absent_rank() {
    // no fault plan at all: the freeze is pure membership semantics.
    // Rank 3 sits out two rounds; its worker RNG and base-optimizer
    // state must stay bit-identical to a worker that never stepped,
    // while the slots are billed as absent and expiry re-admits.
    let cfg = avg_cfg("byz-freeze");
    let mut t = Trainer::with_backend(cfg.clone(), backend()).unwrap();
    t.force_quarantine(3, 2);
    t.step_round().unwrap();
    t.step_round().unwrap();
    assert_eq!(t.fault_stats().absent_ranks, 2, "each frozen round bills one absent slot");
    assert_eq!(t.fault_stats().readmissions, 1, "expiry must re-admit on probation");
    assert_eq!(t.quarantine_rounds_left()[3], 0);

    let frozen = std::env::temp_dir().join("dsm_byz_frozen.ckpt");
    let fresh = std::env::temp_dir().join("dsm_byz_fresh.ckpt");
    t.save_checkpoint(&frozen).unwrap();
    Trainer::with_backend(cfg, backend()).unwrap().save_checkpoint(&fresh).unwrap();
    let ck_frozen = Checkpoint::load(&frozen).unwrap();
    let ck_fresh = Checkpoint::load(&fresh).unwrap();
    std::fs::remove_file(&frozen).ok();
    std::fs::remove_file(&fresh).ok();

    // the frozen rank's state never moved off its initialization …
    let w3_frozen = ck_frozen.with_prefix("worker3.");
    let w3_fresh = ck_fresh.with_prefix("worker3.");
    assert!(!w3_frozen.is_empty());
    assert_eq!(w3_frozen, w3_fresh, "a frozen rank's worker state must not advance");
    // … while the active ranks trained
    assert_ne!(
        ck_frozen.with_prefix("worker0."),
        ck_fresh.with_prefix("worker0."),
        "active ranks must have stepped"
    );
}

#[test]
fn held_rounds_advance_the_schedule_but_not_the_rng_or_outer_state() {
    // drop_prob = 1 under the MV outer — the most trainer-RNG-hungry
    // configuration (randomized sign votes every contribution). A held
    // round must consume none of it: the pin is that the LR schedule
    // and the clock move while the trainer RNG, the outer-optimizer
    // state, and the global parameters all hold.
    let mut cfg = mv_cfg("byz-held");
    cfg.faults.drop_prob = 1.0;
    let mut t = Trainer::with_backend(cfg.clone(), backend()).unwrap();
    let before = t.params().to_vec();
    let r0 = t.step_round().unwrap();
    let r1 = t.step_round().unwrap();
    assert_eq!(t.fault_stats().no_quorum_rounds, 2);
    assert_eq!(t.fault_stats().dropped_payloads, 8);
    assert_ne!(r0.lr, r1.lr, "the LR schedule must advance across held rounds");
    assert_eq!(t.params(), &before[..], "a held round must not move the global");

    let held = std::env::temp_dir().join("dsm_byz_held.ckpt");
    let fresh = std::env::temp_dir().join("dsm_byz_held_fresh.ckpt");
    t.save_checkpoint(&held).unwrap();
    Trainer::with_backend(cfg, backend()).unwrap().save_checkpoint(&fresh).unwrap();
    let ck_held = Checkpoint::load(&held).unwrap();
    let ck_fresh = Checkpoint::load(&fresh).unwrap();
    std::fs::remove_file(&held).ok();
    std::fs::remove_file(&fresh).ok();

    assert_eq!(
        ck_held.get("trainer.rng").unwrap(),
        ck_fresh.get("trainer.rng").unwrap(),
        "held rounds must not consume the trainer RNG"
    );
    let outer_held = ck_held.with_prefix("outer.");
    assert!(!outer_held.is_empty());
    assert_eq!(
        outer_held,
        ck_fresh.with_prefix("outer."),
        "held rounds must not advance the outer-optimizer state"
    );
}

#[test]
fn retries_are_counted_and_a_total_blackout_still_holds() {
    // at drop_prob = 1 every retransmission fails too: the counters
    // pin that each dropped payload got exactly retry_limit re-sends
    // and the round still held with no quorum.
    let mut cfg = avg_cfg("byz-retry");
    cfg.faults.drop_prob = 1.0;
    cfg.faults.retry_limit = 3;
    let mut t = Trainer::with_backend(cfg, backend()).unwrap();
    let res = t.run().unwrap();
    assert_eq!(res.faults.no_quorum_rounds, 4);
    assert_eq!(res.faults.dropped_payloads, 4 * 4);
    assert_eq!(res.faults.retried_payloads, 4 * 4 * 3);
}

#[test]
fn resume_with_an_active_quarantine_is_bit_identical() {
    // checkpoint inside the liar's first freeze window: reputation,
    // quarantine clocks, and backoff all ride the checkpoint, so the
    // resumed run must replay the uninterrupted one bit-for-bit.
    let mut cfg = avg_cfg("byz-resume");
    cfg.rounds = 8;
    cfg.n_workers = 8;
    cfg.faults.byzantine_frac = 0.125; // exactly one liar
    cfg.faults.attack = Attack::ScaleInflate;
    cfg.faults.quarantine = true;
    let mut t_full = Trainer::with_backend(cfg.clone(), backend()).unwrap();
    let full = t_full.run().unwrap();

    let mut half = cfg.clone();
    half.rounds = 4;
    let mut t1 = Trainer::with_backend(half, backend()).unwrap();
    t1.run().unwrap();
    assert!(
        t1.quarantine_rounds_left().iter().any(|&q| q > 0),
        "the checkpoint must land mid-quarantine for this test to bite"
    );
    let path = std::env::temp_dir().join("dsm_byz_resume.ckpt");
    t1.save_checkpoint(&path).unwrap();

    let mut t2 = Trainer::with_backend(cfg, backend()).unwrap();
    t2.load_checkpoint(&path).unwrap();
    let resumed = t2.run().unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(resumed.final_val.to_bits(), full.final_val.to_bits());
    assert_eq!(resumed.faults, full.faults, "fault counters must resume, not restart");
    let (ra, rb) = (t2.reputations(), t_full.reputations());
    assert_eq!(ra.len(), rb.len());
    for (a, b) in ra.iter().zip(rb) {
        assert_eq!(a.to_bits(), b.to_bits(), "reputations must replay bit-for-bit");
    }
    assert_eq!(t2.quarantine_rounds_left(), t_full.quarantine_rounds_left());
}
