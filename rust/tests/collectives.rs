//! Property tests for the `dist` collectives backends and the 1-bit
//! sign codec (same in-tree randomized-property style as properties.rs;
//! proptest is unavailable offline).
//!
//! The headline invariant is the acceptance criterion of the subsystem:
//! the threaded chunked-reduction backend must be **bitwise identical**
//! to the sequential reference for any (n, P, thread-count).

use dsm::dist::codec;
use dsm::dist::collectives::{self, Backend};
use dsm::tensor;
use dsm::util::rng::Rng;

/// Mini property harness: run `f` on `cases` random inputs.
fn forall<F: FnMut(u64, &mut Rng)>(name: &str, cases: u64, mut f: F) {
    for case in 0..cases {
        let mut rng = Rng::new(0xC011_EC71 ^ case);
        f(case, &mut rng);
    }
    let _ = name;
}

fn random_fleet(rng: &mut Rng, n: usize, p: usize, std: f32) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| {
            let mut v = vec![0.0f32; p];
            rng.fill_normal(&mut v, std);
            v
        })
        .collect()
}

#[test]
fn prop_threaded_allreduce_is_bitwise_identical_to_sequential() {
    forall("allreduce-backends", 25, |case, rng| {
        let p = 1 + rng.below(10_000) as usize;
        let n = 1 + rng.below(8) as usize;
        let workers = random_fleet(rng, n, p, 3.0);
        let mut seq = vec![0.0f32; p];
        collectives::allreduce_mean_with(Backend::Sequential, &workers, |w| w.as_slice(), &mut seq);
        for threads in [1usize, 2, 3, 5, 16] {
            let backend = Backend::Threaded { threads };
            let mut thr = vec![0.0f32; p];
            collectives::allreduce_mean_with(backend, &workers, |w| w.as_slice(), &mut thr);
            for j in 0..p {
                assert_eq!(
                    seq[j].to_bits(),
                    thr[j].to_bits(),
                    "case {case}: coord {j} differs with {threads} threads (n={n}, P={p})"
                );
            }
        }
    });
}

#[test]
fn prop_auto_backend_matches_sequential_above_parallel_threshold() {
    // Large enough that Backend::auto goes threaded on multi-core hosts,
    // deliberately not a multiple of any chunk size.
    let p = (1 << 17) + 13;
    let mut rng = Rng::new(1234);
    let workers = random_fleet(&mut rng, 4, p, 1.0);
    let mut seq = vec![0.0f32; p];
    let mut auto = vec![0.0f32; p];
    collectives::allreduce_mean_with(Backend::Sequential, &workers, |w| w.as_slice(), &mut seq);
    collectives::allreduce_mean(&workers, |w| w.as_slice(), &mut auto);
    assert!(
        seq.iter().zip(&auto).all(|(a, b)| a.to_bits() == b.to_bits()),
        "auto backend must be bitwise-equal to the sequential reference"
    );
}

#[test]
fn prop_threaded_majority_vote_matches_sequential() {
    forall("vote-backends", 20, |case, rng| {
        let p = 1 + rng.below(5_000) as usize;
        let n = 1 + rng.below(9) as usize;
        let votes: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..p).map(|_| *rng.choose(&[-1.0f32, 0.0, 1.0])).collect())
            .collect();
        let mut seq = vec![0.0f32; p];
        collectives::majority_vote_with(Backend::Sequential, &votes, &mut seq);
        for threads in [2usize, 4, 11] {
            let mut thr = vec![0.0f32; p];
            collectives::majority_vote_with(Backend::Threaded { threads }, &votes, &mut thr);
            assert_eq!(seq, thr, "case {case}: threads={threads}");
        }
    });
}

#[test]
fn prop_majority_vote_is_pm_one_and_follows_the_tally() {
    forall("vote-semantics", 30, |case, rng| {
        let p = 1 + rng.below(500) as usize;
        let n = 1 + rng.below(8) as usize;
        let votes = random_fleet(rng, n, p, 1.0);
        let mut out = vec![0.0f32; p];
        collectives::majority_vote(&votes, &mut out);
        for j in 0..p {
            assert!(out[j] == 1.0 || out[j] == -1.0, "case {case}: coord {j} = {}", out[j]);
            let tally: i64 = votes.iter().map(|v| tensor::sign_f32(v[j]) as i64).sum();
            // documented tie behavior: zero tallies resolve to +1
            let expect = if tally >= 0 { 1.0 } else { -1.0 };
            assert_eq!(out[j], expect, "case {case}: coord {j}, tally {tally}");
        }
    });
}

#[test]
fn majority_vote_tie_cases_resolve_positive() {
    // exact tie between one +1 and one -1, and an all-zero column
    let votes = vec![vec![1.0f32, 0.0], vec![-1.0f32, 0.0]];
    let mut out = vec![0.0f32; 2];
    collectives::majority_vote(&votes, &mut out);
    assert_eq!(out, vec![1.0, 1.0]);
}

#[test]
fn prop_sign_codec_roundtrips_every_pattern_including_zeros() {
    forall("codec-roundtrip", 40, |case, rng| {
        let p = rng.below(2_000) as usize;
        // arbitrary floats with exact zeros (and negative zeros) mixed in
        let v: Vec<f32> = (0..p)
            .map(|_| match rng.below(5) {
                0 => 0.0,
                1 => -0.0,
                _ => rng.normal_f32(0.0, 2.0),
            })
            .collect();
        let packed = codec::pack_signs(&v);
        assert_eq!(packed.len(), codec::packed_len(p), "case {case}");
        let back = codec::unpack_signs(&packed, p);
        for (j, (&x, &b)) in v.iter().zip(&back).enumerate() {
            assert_eq!(b, 1.0f32.copysign(x), "case {case}: coord {j} (input {x})");
        }
        // pure ±1 sign patterns round-trip exactly
        let signs: Vec<f32> = v.iter().map(|&x| 1.0f32.copysign(x)).collect();
        assert_eq!(codec::unpack_signs(&codec::pack_signs(&signs), p), signs, "case {case}");
    });
}

#[test]
fn prop_codec_compresses_32x_modulo_rounding() {
    forall("codec-size", 20, |case, rng| {
        let p = 1 + rng.below(100_000) as usize;
        let packed = codec::packed_len(p);
        assert!(packed * 8 >= p, "case {case}");
        assert!(packed * 8 < p + 8, "case {case}");
        assert_eq!(codec::sign_allreduce_bytes(p), packed as u64 + codec::HEADER_BYTES);
    });
}

#[test]
fn prop_allreduce_backends_agree_with_plain_mean() {
    forall("allreduce-oracle", 20, |case, rng| {
        let p = 1 + rng.below(300) as usize;
        let n = 1 + rng.below(6) as usize;
        let workers = random_fleet(rng, n, p, 5.0);
        let mut out = vec![0.0f32; p];
        collectives::allreduce_mean(&workers, |w| w.as_slice(), &mut out);
        for j in 0..p {
            let mean: f64 = workers.iter().map(|w| w[j] as f64).sum::<f64>() / n as f64;
            assert!(
                (out[j] as f64 - mean).abs() <= 1e-6 * mean.abs().max(1.0),
                "case {case}: coord {j}: {} vs {mean}",
                out[j]
            );
        }
    });
}
