//! Algorithmic equivalences the paper states, verified numerically
//! (no PJRT needed — pure Rust).

use dsm::optim::{BaseOptimizer, Lion};
use dsm::outer::{run_synthetic_round, Lookahead, OuterOptimizer, SignMomentum, SlowMo};
use dsm::sign::SignOp;
use dsm::tensor;
use dsm::util::rng::Rng;

/// §2 "Algorithm instances": with n=1, τ=1, SGD base and γ-scaled
/// pseudo-gradients, Algorithm 1's global step IS a Lion step on the
/// same gradient stream (same β1, β2, λ, LR = η·γ).
#[test]
fn algorithm1_with_tau1_sgd_is_lion() {
    let d = 64;
    let (b1, b2, lam) = (0.9f32, 0.99, 0.1);
    let (eta, gamma) = (2.0f32, 0.05f32);

    let mut rng = Rng::new(3);
    let mut x_lion: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut x_alg1 = x_lion.clone();

    let mut lion = Lion::new(d, b1, b2, lam);
    let mut alg1 = SignMomentum::new(d, eta, b1, b2, lam, SignOp::Exact, 1.0);

    for round in 0..20 {
        let grads: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // Lion with LR η·γ on gradient g
        lion.step(&mut x_lion, &grads, eta * gamma);
        // Algorithm 1: one SGD local step produces diff = γ·g
        let diff: Vec<f32> = grads.iter().map(|&g| g * gamma).collect();
        run_synthetic_round(&mut alg1, &mut x_alg1, &diff, gamma, round);
    }
    assert!(
        tensor::max_abs_diff(&x_lion, &x_alg1) < 1e-5,
        "max diff {}",
        tensor::max_abs_diff(&x_lion, &x_alg1)
    );
}

/// §4.1: signed Lookahead == Algorithm 1 with β1 = β2, λ = 0 — already
/// unit-tested per-round; here over a long trajectory with varying γ_t.
#[test]
fn signed_lookahead_tracks_algorithm1_under_lr_schedule() {
    let d = 32;
    let beta = 0.7f32;
    let mut la = Lookahead::new(d, 3.0, beta, true);
    let mut sm = SignMomentum::new(d, 3.0, beta, beta, 0.0, SignOp::Exact, 1.0);
    let mut xa = vec![0.4f32; d];
    let mut xb = xa.clone();
    let mut rng = Rng::new(9);
    for round in 0..50 {
        let gamma = 0.1 / (1.0 + round as f32 * 0.1); // decaying schedule
        let diff: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 0.01)).collect();
        run_synthetic_round(&mut la, &mut xa, &diff, gamma, round);
        run_synthetic_round(&mut sm, &mut xb, &diff, gamma, round);
    }
    assert!(tensor::max_abs_diff(&xa, &xb) < 1e-5);
}

/// SlowMo with β=0, α=1 degenerates to plain local averaging over any
/// trajectory (the "LocalAvg is SlowMo's ancestor" relation).
#[test]
fn slowmo_beta0_alpha1_is_local_averaging() {
    let d = 16;
    let mut slowmo = SlowMo::new(d, 1.0, 0.0);
    let mut x = vec![1.0f32; d];
    let mut rng = Rng::new(4);
    for round in 0..10 {
        let diff: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let expect: Vec<f32> = x.iter().zip(&diff).map(|(&xi, &di)| xi - di).collect();
        run_synthetic_round(&mut slowmo, &mut x, &diff, 0.5, round);
        assert!(tensor::max_abs_diff(&x, &expect) < 1e-6);
    }
}

/// The momentum buffer of Algorithm 1 must be invariant to rescaling
/// (γ, diff) jointly — the 1/γ_t normalization working as eq. (6)-(8)
/// intend across an entire schedule.
#[test]
fn momentum_schedule_invariance_over_trajectory() {
    let d = 8;
    let pseudo_grads: Vec<Vec<f32>> =
        (0..30).map(|r| (0..d).map(|j| ((r * d + j) as f32).sin() * 0.1).collect()).collect();
    let mut finals = Vec::new();
    for scale in [1.0f32, 0.37] {
        let mut sm = SignMomentum::new(d, 1.0, 0.95, 0.98, 0.0, SignOp::Exact, 1.0);
        let mut x = vec![0.0f32; d];
        for (r, pg) in pseudo_grads.iter().enumerate() {
            let gamma = 0.05 * scale;
            let diff: Vec<f32> = pg.iter().map(|&g| g * gamma).collect();
            run_synthetic_round(&mut sm, &mut x, &diff, gamma, r as u64);
        }
        finals.push(sm.state()[0].to_vec());
    }
    assert!(tensor::max_abs_diff(&finals[0], &finals[1]) < 1e-5);
}
