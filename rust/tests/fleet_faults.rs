//! Fleet-scale robustness: the hierarchical exchange regime and the
//! fault plan, end to end on the pure-Rust [`NativeBundle`] backend (no
//! PJRT artifacts required).
//!
//! Three contracts are pinned here:
//!
//! 1. **Stream hygiene** — straggler/jitter billing draws from the
//!    trainer's dedicated fault stream, so toggling the comm preset's
//!    jitter can never shift an optimization draw (the training
//!    trajectory is bit-identical across comm presets).
//! 2. **Hierarchical regime** — once the fleet clears
//!    `HIERARCHICAL_MIN_RANKS`, compressed wires route the two-level
//!    topology: parallel ≡ sequential still holds bitwise, and the
//!    billed volume stays the flat `2(n−1)·b` per round.
//! 3. **Faults** — dropped payloads shrink `n_effective` without
//!    killing the round (majority vote holds its loss), corrupted
//!    payloads are rejected loudly (counted, never averaged in), and a
//!    faulty run checkpoints/resumes bit-for-bit.

use std::sync::Arc;

use dsm::config::RunConfig;
use dsm::outer::OuterConfig;
use dsm::runtime::NativeBundle;
use dsm::train::{RunResult, Trainer};

const PRESET: &str = "native";

/// ln(256), the byte LM's uniform loss — the "did not diverge" anchor.
fn uniform() -> f64 {
    (256f64).ln()
}

fn backend() -> Arc<NativeBundle> {
    Arc::new(NativeBundle::new(PRESET, 2, 24, 8))
}

fn base_cfg(tag: &str) -> RunConfig {
    let mut cfg = RunConfig::paper_default(PRESET);
    cfg.rounds = 4;
    cfg.tau = 3;
    cfg.n_workers = 4;
    cfg.corpus_bytes = 1 << 16;
    cfg.eval_every = 2;
    cfg.eval_batches = 2;
    cfg.comm = dsm::comm::CommModel::preset("ethernet").unwrap();
    cfg.tag = tag.to_string();
    cfg
}

fn mv_cfg(tag: &str) -> RunConfig {
    let mut cfg = base_cfg(tag);
    cfg.outer = OuterConfig::MvSignSgd { eta: 1e-3, beta: 0.9, alpha: 0.1, bound: 50.0 };
    cfg
}

fn run_cfg(cfg: RunConfig) -> RunResult {
    let mut t = Trainer::with_backend(cfg, backend()).unwrap();
    t.run().unwrap()
}

fn assert_same_trajectory(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(a.log.rows.len(), b.log.rows.len(), "{label}: row count");
    for (ra, rb) in a.log.rows.iter().zip(&b.log.rows) {
        assert_eq!(
            ra.train_loss.to_bits(),
            rb.train_loss.to_bits(),
            "{label}: train loss, round {}",
            ra.round
        );
        assert_eq!(
            ra.val_loss.to_bits(),
            rb.val_loss.to_bits(),
            "{label}: val loss, round {}",
            ra.round
        );
    }
    assert_eq!(a.final_val.to_bits(), b.final_val.to_bits(), "{label}: final val");
}

#[test]
fn comm_jitter_cannot_shift_the_training_stream() {
    // mv_signsgd's randomized sign votes consume the trainer RNG every
    // round, so this is the most jitter-sensitive configuration: if
    // straggler draws shared that stream, swapping the comm preset
    // would shift every vote. They live on the dedicated fault stream
    // instead — the trajectory is bit-identical, only the clock moves.
    let mut free = mv_cfg("jitter-free");
    free.comm = dsm::comm::CommModel::preset("none").unwrap();
    let mut wan = mv_cfg("jitter-wan");
    wan.comm = dsm::comm::CommModel::preset("wan").unwrap();
    let rf = run_cfg(free);
    let rw = run_cfg(wan);
    assert_same_trajectory(&rf, &rw, "jitter toggle");
    assert_eq!(rf.clock.straggler_s, 0.0);
    assert!(rw.clock.straggler_s > 0.0, "wan jitter must bill straggler time");
}

#[test]
fn hierarchical_regime_is_parallel_sequential_identical_and_bills_flat_volume() {
    // n = 32 clears HIERARCHICAL_MIN_RANKS, so the q8 wire routes the
    // two-level topology every round: the group heads' decode-mean-
    // requantize data path must stay bitwise execution-order-invariant,
    // and the billed volume must stay the flat 2(n−1)·b.
    let mut cfg = base_cfg("hier-fleet");
    cfg.n_workers = 32;
    cfg.rounds = 2;
    cfg.tau = 2;
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8);
    let mut seq = cfg.clone();
    seq.sequential_workers = true;

    let mut par_t = Trainer::with_backend(cfg, backend()).unwrap();
    let p = par_t.dim();
    let par = par_t.run().unwrap();
    let seq = run_cfg(seq);
    assert_same_trajectory(&par, &seq, "hierarchical n=32");

    let payload = dsm::dist::codec::q8_bytes(p);
    assert_eq!(par.clock.bytes_communicated, 2 * payload * 2 * (32 - 1));
    assert_eq!(par.clock.bytes_communicated, seq.clock.bytes_communicated);
}

#[test]
fn hierarchical_regime_checkpoint_resume_is_bit_identical() {
    let mut cfg = base_cfg("hier-ck");
    cfg.n_workers = 16;
    cfg.rounds = 4;
    cfg.tau = 2;
    cfg.eval_every = 0;
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8PerTensor);
    let full = run_cfg(cfg.clone());

    let mut half = cfg.clone();
    half.rounds = 2;
    let mut t1 = Trainer::with_backend(half, backend()).unwrap();
    t1.run().unwrap();
    let path = std::env::temp_dir().join("dsm_fleet_hier_resume.ckpt");
    t1.save_checkpoint(&path).unwrap();

    let mut t2 = Trainer::with_backend(cfg, backend()).unwrap();
    t2.load_checkpoint(&path).unwrap();
    let resumed = t2.run().unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(resumed.final_val.to_bits(), full.final_val.to_bits());
    assert_eq!(resumed.clock.bytes_communicated, full.clock.bytes_communicated);
}

#[test]
fn majority_vote_holds_its_loss_under_ten_percent_drops() {
    // the acceptance pin: at drop_prob = 0.1 the MV tally thresholds at
    // half of whatever arrived, so the run neither errors nor collapses
    // — final loss stays in the same neighborhood as the drop-free run.
    let clean = run_cfg(mv_cfg("mv-clean"));
    let mut faulty_cfg = mv_cfg("mv-drops");
    faulty_cfg.faults.drop_prob = 0.1;
    let faulty = run_cfg(faulty_cfg);

    assert!(faulty.faults.dropped_payloads > 0, "0.1 × 16 payloads should drop at least one");
    assert_eq!(clean.faults.dropped_payloads, 0);
    assert!(faulty.final_val.is_finite());
    assert!(faulty.final_val < uniform() + 0.5, "diverged: {}", faulty.final_val);
    assert!(
        (faulty.final_val - clean.final_val).abs() < 0.5,
        "drops moved the loss too far: {} vs {}",
        faulty.final_val,
        clean.final_val
    );
}

#[test]
fn dense_corruption_is_rejected_loudly_never_averaged() {
    // a corrupted dense payload carries a NaN coordinate; the
    // finiteness check excludes it from the round and counts it. The
    // run completes with a finite global — the poison never reaches
    // the mean.
    let mut cfg = base_cfg("dense-corrupt");
    cfg.rounds = 6;
    cfg.faults.corrupt_prob = 0.5;
    let res = run_cfg(cfg);
    assert!(res.faults.corrupted_payloads > 0, "0.5 × 24 payloads should corrupt some");
    // every corrupted dense payload is NaN-poisoned, hence rejected
    assert_eq!(res.faults.rejected_payloads, res.faults.corrupted_payloads);
    assert!(res.final_val.is_finite());
    for row in &res.log.rows {
        assert!(row.train_loss.is_finite(), "round {}", row.round);
    }
}

#[test]
fn quantized_corruption_splits_into_survived_flips_and_rejected_scales() {
    // q8 corruption is a fair coin between a flipped byte (valid
    // encoding — survived with bounded error) and a NaN scale
    // (rejected): over 6 rounds × 4 ranks at corrupt_prob 0.5, both
    // fates should occur, and rejections never exceed corruptions.
    let mut cfg = base_cfg("q8-corrupt");
    cfg.rounds = 6;
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8);
    cfg.faults.corrupt_prob = 0.5;
    let res = run_cfg(cfg);
    assert!(res.faults.corrupted_payloads > 0);
    assert!(res.faults.rejected_payloads < res.faults.corrupted_payloads);
    assert!(res.final_val.is_finite());
}

#[test]
fn elastic_membership_trains_through_churn() {
    let mut cfg = base_cfg("churn");
    cfg.rounds = 6;
    cfg.faults.churn_prob = 0.3;
    let res = run_cfg(cfg);
    assert!(res.faults.absent_ranks > 0, "0.3 × 24 rank-rounds should sit some out");
    assert!(res.final_val.is_finite());
    assert!(res.final_val < uniform() + 0.5, "churned fleet diverged: {}", res.final_val);
}

#[test]
fn total_drop_yields_no_quorum_rounds_and_a_held_global() {
    // drop_prob = 1: nothing ever arrives, every round is a no-quorum
    // round, and the global holds at the round start instead of
    // erroring — the loudness lives in the counters.
    let mut cfg = base_cfg("blackout");
    cfg.faults.drop_prob = 1.0;
    let res = run_cfg(cfg);
    assert_eq!(res.faults.no_quorum_rounds, 4);
    assert_eq!(res.faults.dropped_payloads, 4 * 4);
    assert!(res.final_val.is_finite());
    // with no aggregate ever applied, the global never moves: every
    // eval sees the same initial parameters
    let rows = &res.log.rows;
    let evals: Vec<u64> =
        rows.iter().filter(|r| !r.val_loss.is_nan()).map(|r| r.val_loss.to_bits()).collect();
    assert!(evals.len() >= 2);
    assert!(evals.windows(2).all(|w| w[0] == w[1]), "global moved during a blackout");
}

#[test]
fn faulty_run_checkpoint_resume_is_bit_identical() {
    // churn + drops + corruption + heavy tails all draw from the
    // checkpointed fault stream: a resumed run must replay the
    // uninterrupted one bit-for-bit, counters included.
    let mut cfg = mv_cfg("faulty-ck");
    cfg.rounds = 6;
    cfg.eval_every = 0;
    cfg.faults.churn_prob = 0.2;
    cfg.faults.drop_prob = 0.15;
    cfg.faults.corrupt_prob = 0.1;
    cfg.faults.tail_prob = 0.3;
    cfg.faults.tail_scale_s = 2.0;
    let full = run_cfg(cfg.clone());

    let mut half = cfg.clone();
    half.rounds = 3;
    let mut t1 = Trainer::with_backend(half, backend()).unwrap();
    t1.run().unwrap();
    let path = std::env::temp_dir().join("dsm_fleet_faulty_resume.ckpt");
    t1.save_checkpoint(&path).unwrap();

    let mut t2 = Trainer::with_backend(cfg, backend()).unwrap();
    t2.load_checkpoint(&path).unwrap();
    let resumed = t2.run().unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(resumed.final_val.to_bits(), full.final_val.to_bits());
    assert_eq!(resumed.faults, full.faults, "fault counters must resume, not restart");
    assert_eq!(
        resumed.clock.straggler_s.to_bits(),
        full.clock.straggler_s.to_bits(),
        "heavy-tail stalls must replay from the checkpointed fault stream"
    );
    assert_eq!(resumed.clock.bytes_communicated, full.clock.bytes_communicated);
    assert!(full.faults.absent_ranks + full.faults.dropped_payloads > 0, "plan never fired");
}

#[test]
fn degraded_rounds_bill_fewer_bytes_than_clean_ones() {
    // q8 (server topology both ways): a clean round moves 2(n−1)·b,
    // a degraded one (arrived−1 + n_active−1)·b — dropped payloads
    // never reached the server and must not be billed. (Dense is
    // excluded on purpose: its clean path is the cheaper ring, so the
    // byte comparison would go the other way.)
    let mut clean_cfg = base_cfg("bill-clean");
    clean_cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8);
    let clean = run_cfg(clean_cfg);
    let mut cfg = base_cfg("bill-drops");
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8);
    cfg.faults.drop_prob = 0.5;
    let faulty = run_cfg(cfg);
    assert!(faulty.faults.dropped_payloads > 0);
    assert!(
        faulty.clock.bytes_communicated < clean.clock.bytes_communicated,
        "dropped payloads never reached the server; they must not be billed: {} vs {}",
        faulty.clock.bytes_communicated,
        clean.clock.bytes_communicated
    );
}
