//! Tier-1 gate: the in-tree invariant linter (`tools/invlint`) must pass
//! on `rust/src`. This is the same pass `cargo run -p invlint` executes;
//! wiring it into `cargo test -q` means deleting a `WirePayload` match
//! arm, dropping a checkpoint save-key read, or parking a config knob
//! outside `describe()` fails the build, not just the CI lint job.

use std::path::Path;

#[test]
fn live_tree_passes_invlint() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let violations = match invlint::lint_tree(&root) {
        Ok(v) => v,
        Err(e) => panic!("cannot walk {}: {e}", root.display()),
    };
    assert!(
        violations.is_empty(),
        "invlint found {} violation(s) in rust/src:\n{}",
        violations.len(),
        invlint::render(&violations)
    );
}
