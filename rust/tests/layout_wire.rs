//! Golden tests for the layout-aware wire: the per-tensor `q8pt`
//! format against the per-message `q8` reference.
//!
//! Two pinned facts:
//!
//! 1. **One-segment identity** — under a single-segment layout, `q8pt`
//!    is *bitwise*-identical to `q8`: same quantization scale, same
//!    payload bytes, same reconstructed mean (the per-segment codec
//!    runs the identical arithmetic over the identical range, and the
//!    server mean iterates segment-major in coordinate order).
//! 2. **Hetero-magnitude error reduction** — on a two-segment layout
//!    whose segments move at very different magnitudes, per-tensor
//!    scales strictly reduce the max dequantization error; the exact
//!    error values are pinned numerically.

use std::sync::Arc;

use dsm::dist::codec;
use dsm::dist::{WireFormat, WirePayload};
use dsm::runtime::{ParamEntry, ParamLayout};

fn layout_of(sizes: &[usize]) -> Arc<ParamLayout> {
    let mut entries = Vec::new();
    let mut off = 0usize;
    for (i, &n) in sizes.iter().enumerate() {
        entries.push(ParamEntry { name: format!("seg{i}"), offset: off, shape: vec![n] });
        off += n;
    }
    Arc::new(ParamLayout::from_entries(entries, off).unwrap())
}

/// Deterministic pseudo-random-ish test vectors (no RNG dependency).
fn wiggle(n: usize, scale: f32, phase: f32) -> Vec<f32> {
    (0..n).map(|i| scale * ((i as f32) * 0.7 + phase).sin()).collect()
}

#[test]
fn one_segment_q8pt_is_bitwise_identical_to_q8() {
    let p = 257; // deliberately not a power of two
    let start = wiggle(p, 1.0, 0.0);
    let diffs = [wiggle(p, 0.01, 1.0), wiggle(p, 0.02, 2.0), wiggle(p, 0.005, 3.0)];
    let ends: Vec<Vec<f32>> = diffs
        .iter()
        .map(|d| start.iter().zip(d).map(|(&s, &x)| s - x).collect())
        .collect();

    let pack_all = |format: WireFormat| -> Vec<WirePayload> {
        ends.iter()
            .map(|end| {
                let mut pl = WirePayload::with_len(format, p);
                pl.pack_end(&start, end);
                pl
            })
            .collect()
    };
    let q8 = pack_all(WireFormat::QuantizedI8);
    let q8pt = pack_all(WireFormat::QuantizedI8PerTensor);

    for (a, b) in q8.iter().zip(&q8pt) {
        // identical scale, bit for bit
        let sa = a.scales().unwrap();
        let sb = b.scales().unwrap();
        assert_eq!(sa.len(), 1);
        assert_eq!(sb.len(), 1);
        assert_eq!(sa[0].to_bits(), sb[0].to_bits());
        // identical payload bytes
        let WirePayload::QuantizedI8 { bytes: ba, .. } = a else { panic!("expected q8") };
        let WirePayload::QuantizedI8PerTensor { bytes: bb, .. } = b else {
            panic!("expected q8pt")
        };
        assert_eq!(ba, bb);
        // identical wire cost: one segment means one scale either way
        assert_eq!(a.wire_bytes(), b.wire_bytes());
    }

    // identical server-side reconstruction, bit for bit
    let mut mean_q8 = vec![0.0f32; p];
    WirePayload::mean_end_into(&q8, &start, &mut mean_q8).unwrap();
    let mut mean_q8pt = vec![0.0f32; p];
    WirePayload::mean_end_into(&q8pt, &start, &mut mean_q8pt).unwrap();
    for (a, b) in mean_q8.iter().zip(&mean_q8pt) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn hetero_two_segment_layout_strictly_reduces_max_dequantization_error() {
    // segment 0 moves by ≤ 1e-3, segment 1 by up to 1.27: the shared q8
    // scale is 1.27/127 = 0.01, so every |diff| < 0.005 in segment 0
    // rounds to byte 0 — a 100% relative error. Per-tensor scales give
    // segment 0 its own 1e-3/127 step.
    let layout = layout_of(&[6, 6]);
    let start = vec![0.0f32; 12];
    // segment 1's values are exact integer multiples of the shared
    // 0.01 step, so its q8 decode errors are float-noise-sized and the
    // q8 max error is exactly segment 0's zeroed-out 1e-3
    #[rustfmt::skip]
    let diff = vec![
        1e-3f32, -5e-4, 2.5e-4, -1e-3, 7.5e-4, 0.0, // segment 0: tiny
        1.27, -0.64, 0.32, -1.27, 0.95, 0.1,        // segment 1: large
    ];
    let end: Vec<f32> = start.iter().zip(&diff).map(|(&s, &d)| s - d).collect();

    let mut q8 = WirePayload::with_len(WireFormat::QuantizedI8, 12);
    q8.pack_end(&start, &end);
    let mut q8pt = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
    q8pt.pack_end(&start, &end);

    // pinned scales: shared = 1.27/127 = 0.01 exactly (in f32);
    // per-tensor = [1e-3/127, 0.01]
    let shared = q8.scales().unwrap()[0];
    assert_eq!(shared, 1.27f32 / 127.0);
    let per = q8pt.scales().unwrap();
    assert_eq!(per.len(), 2);
    assert_eq!(per[0], 1e-3f32 / 127.0);
    assert_eq!(per[1].to_bits(), shared.to_bits());

    // decode both and compare against the true difference
    let max_err = |pl: &WirePayload| -> f32 {
        let mut avg = vec![0.0f32; 12];
        WirePayload::mean_end_into(std::slice::from_ref(pl), &start, &mut avg).unwrap();
        avg.iter().zip(&end).map(|(a, e)| (a - e).abs()).fold(0.0f32, f32::max)
    };
    let err_q8 = max_err(&q8);
    let err_q8pt = max_err(&q8pt);

    // q8's worst coordinate is the 1e-3 diff rounding to 0: error
    // exactly 1e-3 (byte = round(1e-3/0.01) = 0)
    assert!((err_q8 - 1e-3).abs() < 1e-7, "q8 max error {err_q8}");
    // per-tensor: segment 0 decodes within half its own step
    // (~3.9e-6) and segment 1's exact-multiple values decode to float
    // noise, so the max error collapses to segment 0's half-step
    assert!(err_q8pt <= per[0] / 2.0 + 1e-7, "q8pt max error {err_q8pt}");
    // the strict reduction, with two orders of magnitude to spare
    assert!(err_q8pt * 100.0 < err_q8, "per-tensor {err_q8pt} must beat per-message {err_q8}");
}

#[test]
fn q8pt_wire_cost_is_q8_plus_one_scale_per_extra_segment() {
    let p = 10_000;
    for segs in [1usize, 2, 7, 64] {
        let sizes: Vec<usize> = (0..segs).map(|i| p / segs + usize::from(i < p % segs)).collect();
        let layout = layout_of(&sizes);
        assert_eq!(layout.param_count(), p);
        let pl = WirePayload::with_layout(WireFormat::QuantizedI8PerTensor, &layout);
        assert_eq!(pl.wire_bytes(), codec::q8_bytes(p) + 4 * (segs as u64 - 1));
        assert_eq!(pl.wire_bytes(), codec::q8pt_bytes(p, segs));
    }
}
