//! Differential property suite for the compressed wire codecs (same
//! in-tree randomized-property style as collectives.rs; proptest is
//! unavailable offline).
//!
//! The headline invariant for the 1-bit path is ISSUE 2's acceptance
//! criterion: for any (n workers, P dims, thread count) — including
//! signed zeros, exact ties, and P not divisible by 8 or 64 —
//! `majority_vote_packed` over the packed payloads is **bitwise
//! identical** to the f32 `majority_vote` over the unpacked votes, on
//! both backends. The q8 properties pin the QuantizedI8 payload's
//! round-trip error bound and wire-byte exactness (ISSUE 4).

use dsm::dist::codec;
use dsm::dist::collectives::{self, Backend};
use dsm::dist::votes::{self, PackedVotes};
use dsm::dist::{WireFormat, WirePayload};
use dsm::util::rng::Rng;

/// Mini property harness: run `f` on `cases` random inputs.
fn forall<F: FnMut(u64, &mut Rng)>(name: &str, cases: u64, mut f: F) {
    for case in 0..cases {
        let mut rng = Rng::new(0x9AC4_ED00 ^ case);
        f(case, &mut rng);
    }
    let _ = name;
}

/// Random vote vector mixing arbitrary magnitudes with ±0.0 (the wire
/// encodes the IEEE sign bit, so signed zeros are first-class votes).
fn random_votes(rng: &mut Rng, p: usize) -> Vec<f32> {
    (0..p)
        .map(|_| match rng.below(6) {
            0 => 0.0,
            1 => -0.0,
            2 => 1.0,
            3 => -1.0,
            _ => rng.normal_f32(0.0, 2.0),
        })
        .collect()
}

#[test]
fn prop_packed_tally_is_bitwise_identical_to_f32_majority_vote() {
    forall("packed-vs-f32", 30, |case, rng| {
        // deliberately hit P % 8 != 0 and P % 64 != 0 often
        let p = 1 + rng.below(3_000) as usize;
        let n = 1 + rng.below(9) as usize;
        let raw: Vec<Vec<f32>> = (0..n).map(|_| random_votes(rng, p)).collect();
        let packed: Vec<PackedVotes> = raw.iter().map(|v| PackedVotes::pack(v)).collect();
        let unpacked: Vec<Vec<f32>> = packed.iter().map(|v| v.unpack()).collect();
        for backend in [
            Backend::Sequential,
            Backend::Threaded { threads: 2 },
            Backend::Threaded { threads: 3 },
            Backend::Threaded { threads: 16 },
        ] {
            let mut from_packed = vec![0.0f32; p];
            votes::majority_vote_packed_with(backend, &packed, &mut from_packed);
            let mut from_f32 = vec![0.0f32; p];
            collectives::majority_vote_with(backend, &unpacked, &mut from_f32);
            for j in 0..p {
                assert_eq!(
                    from_packed[j].to_bits(),
                    from_f32[j].to_bits(),
                    "case {case}: coord {j} differs ({backend:?}, n={n}, P={p})"
                );
            }
        }
    });
}

#[test]
fn prop_threaded_packed_tally_matches_sequential() {
    forall("packed-backends", 20, |case, rng| {
        let p = 1 + rng.below(10_000) as usize;
        let n = 1 + rng.below(8) as usize;
        let packed: Vec<PackedVotes> =
            (0..n).map(|_| PackedVotes::pack(&random_votes(rng, p))).collect();
        let mut seq = vec![0.0f32; p];
        votes::majority_vote_packed_with(Backend::Sequential, &packed, &mut seq);
        for threads in [1usize, 2, 5, 11] {
            let mut thr = vec![0.0f32; p];
            votes::majority_vote_packed_with(
                Backend::Threaded { threads },
                &packed,
                &mut thr,
            );
            assert_eq!(seq, thr, "case {case}: threads={threads} (n={n}, P={p})");
        }
    });
}

#[test]
fn auto_backend_packed_tally_matches_sequential_above_threshold() {
    // large enough that Backend::auto goes threaded on multi-core
    // hosts, deliberately not a multiple of 64
    let p = (1 << 17) + 13;
    let mut rng = Rng::new(4242);
    let packed: Vec<PackedVotes> =
        (0..5).map(|_| PackedVotes::pack(&random_votes(&mut rng, p))).collect();
    let mut seq = vec![0.0f32; p];
    votes::majority_vote_packed_with(Backend::Sequential, &packed, &mut seq);
    let mut auto = vec![0.0f32; p];
    votes::majority_vote_packed(&packed, &mut auto);
    assert!(
        seq.iter().zip(&auto).all(|(a, b)| a.to_bits() == b.to_bits()),
        "auto backend must be bitwise-equal to the sequential reference"
    );
}

#[test]
fn exact_ties_and_signed_zeros_decode_like_the_wire() {
    // one +1 vs one -1, +0.0 vs -0.0, and unanimous ±0.0 columns: every
    // tie decodes +1 on both paths, zeros vote their sign bit
    let a = vec![1.0f32, 0.0, 0.0, -0.0];
    let b = vec![-1.0f32, -0.0, 0.0, -0.0];
    let packed = vec![PackedVotes::pack(&a), PackedVotes::pack(&b)];
    let mut out = vec![0.0f32; 4];
    votes::majority_vote_packed(&packed, &mut out);
    // tie -> +1; (+0,-0) tie -> +1; (+0,+0) -> +1; (-0,-0) -> -1
    assert_eq!(out, vec![1.0, 1.0, 1.0, -1.0]);
    let unpacked: Vec<Vec<f32>> = packed.iter().map(|v| v.unpack()).collect();
    let mut reference = vec![0.0f32; 4];
    collectives::majority_vote(&unpacked, &mut reference);
    assert_eq!(out, reference);
}

#[test]
fn prop_every_packed_result_is_pm_one_and_follows_the_popcount() {
    forall("packed-oracle", 25, |case, rng| {
        let p = 1 + rng.below(400) as usize;
        let n = 1 + rng.below(10) as usize;
        let raw: Vec<Vec<f32>> = (0..n).map(|_| random_votes(rng, p)).collect();
        let packed: Vec<PackedVotes> = raw.iter().map(|v| PackedVotes::pack(v)).collect();
        let mut out = vec![0.0f32; p];
        votes::majority_vote_packed(&packed, &mut out);
        for j in 0..p {
            assert!(out[j] == 1.0 || out[j] == -1.0, "case {case}: coord {j}");
            // scalar oracle: count ranks voting +1 (sign bit clear)
            let count = raw.iter().filter(|v| !v[j].is_sign_negative()).count();
            let expect = if 2 * count >= n { 1.0 } else { -1.0 };
            assert_eq!(out[j], expect, "case {case}: coord {j} ({count}/{n} positive)");
        }
    });
}

#[test]
fn wire_bytes_match_the_codec_cost_model() {
    forall("wire-bytes", 15, |case, rng| {
        let p = rng.below(50_000) as usize;
        let v = random_votes(rng, p);
        let packed = PackedVotes::pack(&v);
        assert_eq!(packed.len(), p, "case {case}");
        assert_eq!(packed.as_bytes().len(), codec::packed_len(p), "case {case}");
        assert_eq!(packed.wire_bytes(), codec::sign_allreduce_bytes(p), "case {case}");
    });
}

// ---- QuantizedI8 payload properties --------------------------------

/// Random difference vector with mixed magnitudes and exact zeros.
fn random_diffs(rng: &mut Rng, p: usize) -> (Vec<f32>, Vec<f32>) {
    let start: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let end: Vec<f32> = start
        .iter()
        .map(|&s| match rng.below(4) {
            0 => s, // exact zero difference
            1 => s - rng.normal_f32(0.0, 1e-4),
            _ => s - rng.normal_f32(0.0, 0.1),
        })
        .collect();
    (start, end)
}

#[test]
fn prop_q8_round_trip_error_is_within_half_a_step() {
    forall("q8-roundtrip", 25, |case, rng| {
        let p = 1 + rng.below(5_000) as usize;
        let (start, end) = random_diffs(rng, p);
        let mut bytes = Vec::new();
        let scale = codec::quantize_diff_into(&start, &end, &mut bytes);
        assert_eq!(bytes.len(), p, "case {case}");
        let max = start.iter().zip(&end).map(|(&s, &e)| (s - e).abs()).fold(0.0f32, f32::max);
        assert!((scale - max / 127.0).abs() <= f32::EPSILON * max, "case {case}: scale");
        for (j, ((&s, &e), &b)) in start.iter().zip(&end).zip(&bytes).enumerate() {
            let err = (codec::dequantize_i8(b, scale) - (s - e)).abs();
            // half a quantization step plus f32 rounding slack
            assert!(
                err <= scale * 0.5 + max * 1e-5,
                "case {case} coord {j}: err {err} vs step {scale}"
            );
        }
    });
}

#[test]
fn prop_q8_payload_wire_bytes_are_exact() {
    forall("q8-wire-bytes", 15, |case, rng| {
        let p = rng.below(50_000) as usize;
        let (start, end) = random_diffs(rng, p);
        let mut payload = WirePayload::with_len(WireFormat::QuantizedI8, p);
        payload.pack_end(&start, &end);
        assert_eq!(payload.len(), p, "case {case}");
        assert_eq!(payload.wire_bytes(), codec::q8_bytes(p), "case {case}");
        assert_eq!(payload.wire_bytes(), WireFormat::QuantizedI8.wire_bytes(p, 1), "case {case}");
        // packing never changes the billed size — the invariant the
        // trainer's bill-before-pack ordering rests on
        let before = WirePayload::with_len(WireFormat::QuantizedI8, p).wire_bytes();
        assert_eq!(payload.wire_bytes(), before, "case {case}");
    });
}

#[test]
fn prop_q8_mean_end_tracks_exact_mean() {
    forall("q8-mean", 15, |case, rng| {
        let p = 1 + rng.below(2_000) as usize;
        let n = 1 + rng.below(6) as usize;
        let start: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let ends: Vec<Vec<f32>> = (0..n)
            .map(|_| start.iter().map(|&s| s - rng.normal_f32(0.0, 0.05)).collect())
            .collect();
        let payloads: Vec<WirePayload> = ends
            .iter()
            .map(|e| {
                let mut pl = WirePayload::with_len(WireFormat::QuantizedI8, p);
                pl.pack_end(&start, e);
                pl
            })
            .collect();
        let mut approx = vec![0.0f32; p];
        WirePayload::mean_end_into(&payloads, &start, &mut approx).unwrap();
        let mut exact = vec![0.0f32; p];
        collectives::allreduce_mean(&ends, |e| e.as_slice(), &mut exact);
        // the mean's error is bounded by the mean of the per-rank
        // half-steps; bound loosely via the largest per-rank scale
        let max_scale = payloads
            .iter()
            .map(|pl| match pl {
                WirePayload::QuantizedI8 { scale, .. } => *scale,
                _ => unreachable!(),
            })
            .fold(0.0f32, f32::max);
        for j in 0..p {
            assert!(
                (approx[j] - exact[j]).abs() <= max_scale * 0.5 + 1e-5,
                "case {case} coord {j}: {} vs {}",
                approx[j],
                exact[j]
            );
        }
    });
}
