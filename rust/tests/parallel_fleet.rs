//! Differential proof of the parallel worker fleet: executing the n
//! simulated ranks concurrently on the persistent pool is
//! **bitwise-identical** to the `cfg.sequential_workers` reference path
//! — loss curves, final parameters, checkpoints (base/outer optimizer
//! state), and every RNG stream — for every outer optimizer, several
//! worker counts, both train modes, both vote data paths, every wire
//! format, and both native backends (the 2-matrix MLP and the
//! multi-layer transformer).
//!
//! Everything here runs on the pure-Rust [`NativeBundle`] backends, so
//! the suite needs no PJRT artifacts and exercises the real `Trainer`
//! end to end in any build environment.

use std::sync::Arc;

use dsm::config::{RunConfig, TrainMode};
use dsm::outer::OuterConfig;
use dsm::runtime::{NativeBundle, StepBackend};
use dsm::train::{RunResult, Trainer};

const PRESET: &str = "native";

fn backend() -> Arc<NativeBundle> {
    // batch 2 × seq 24 × d_model 8 -> P = 4096: small enough to keep the
    // whole suite fast, big enough that every code path does real work
    Arc::new(NativeBundle::new(PRESET, 2, 24, 8))
}

fn transformer_backend() -> Arc<NativeBundle> {
    // 2 blocks of single-head attention + MLP: the non-trivial layout
    // (2 + 6·2 + 1 = 15 named segments) the q8pt wire resolves
    Arc::new(NativeBundle::transformer(PRESET, 2, 12, 8, 2))
}

fn base_cfg(tag: &str) -> RunConfig {
    let mut cfg = RunConfig::paper_default(PRESET);
    cfg.rounds = 4;
    cfg.tau = 3;
    cfg.n_workers = 4;
    cfg.corpus_bytes = 1 << 16;
    cfg.eval_every = 2;
    cfg.eval_batches = 2;
    cfg.comm = dsm::comm::CommModel::preset("ethernet").unwrap();
    cfg.tag = tag.to_string();
    cfg
}

fn run_cfg_on(cfg: RunConfig, be: Arc<NativeBundle>) -> RunResult {
    let mut t = Trainer::with_backend(cfg, be).unwrap();
    t.run().unwrap()
}

fn run_cfg(cfg: RunConfig) -> RunResult {
    run_cfg_on(cfg, backend())
}

/// Run `cfg` twice on `be` — parallel fleet vs sequential reference —
/// and assert the trajectories agree to the last bit: every log row,
/// the final validation loss, and the full checkpoint contents (global
/// params, outer state, per-worker optimizer state, all RNG streams).
fn assert_parallel_equals_sequential_on(cfg: RunConfig, be: Arc<NativeBundle>) {
    let label = cfg.tag.clone();
    let mut par_cfg = cfg.clone();
    par_cfg.sequential_workers = false;
    let mut seq_cfg = cfg;
    seq_cfg.sequential_workers = true;

    let mut par = Trainer::with_backend(par_cfg, be.clone()).unwrap();
    let rp = par.run().unwrap();
    let mut seq = Trainer::with_backend(seq_cfg, be).unwrap();
    let rs = seq.run().unwrap();

    assert_eq!(rp.log.rows.len(), rs.log.rows.len(), "{label}: row count");
    for (a, b) in rp.log.rows.iter().zip(&rs.log.rows) {
        assert_eq!(
            a.train_loss.to_bits(),
            b.train_loss.to_bits(),
            "{label}: train loss, round {}",
            a.round
        );
        assert_eq!(
            a.val_loss.to_bits(),
            b.val_loss.to_bits(),
            "{label}: val loss, round {}",
            a.round
        );
        // modeled comm/straggler charges draw from the dedicated fault
        // stream, so they too must be unaffected by the execution mode
        // (compute seconds are measured wall-clock and are excluded)
        assert_eq!(a.comm_rounds, b.comm_rounds, "{label}: comm rounds");
        assert_eq!(a.local_steps, b.local_steps, "{label}: local steps");
    }
    assert_eq!(rp.final_val.to_bits(), rs.final_val.to_bits(), "{label}: final val");
    // per-segment update norms are derived from bit-identical states,
    // so they too must agree exactly
    assert_eq!(rp.segment_norms.len(), rs.segment_norms.len(), "{label}: segment count");
    for (a, b) in rp.segment_norms.iter().zip(&rs.segment_norms) {
        assert_eq!(a.name, b.name, "{label}: segment order");
        assert_eq!(a.l2.to_bits(), b.l2.to_bits(), "{label}: {} l2", a.name);
        assert_eq!(a.linf.to_bits(), b.linf.to_bits(), "{label}: {} linf", a.name);
    }
    assert_eq!(
        rp.clock.comm_s.to_bits(),
        rs.clock.comm_s.to_bits(),
        "{label}: modeled comm seconds"
    );
    assert_eq!(
        rp.clock.straggler_s.to_bits(),
        rs.clock.straggler_s.to_bits(),
        "{label}: straggler seconds"
    );
    assert_eq!(rp.clock.bytes_communicated, rs.clock.bytes_communicated, "{label}: wire bytes");

    // checkpoints capture params + optimizer state + RNG streams; the
    // two must be byte-for-byte interchangeable
    let dir = std::env::temp_dir().join("dsm_parallel_fleet");
    std::fs::create_dir_all(&dir).unwrap();
    let pp = dir.join(format!("{}-par.ckpt", label.replace('/', "_")));
    let sp = dir.join(format!("{}-seq.ckpt", label.replace('/', "_")));
    par.save_checkpoint(&pp).unwrap();
    seq.save_checkpoint(&sp).unwrap();
    let ck_par = dsm::train::checkpoint::Checkpoint::load(&pp).unwrap();
    let ck_seq = dsm::train::checkpoint::Checkpoint::load(&sp).unwrap();
    std::fs::remove_file(&pp).ok();
    std::fs::remove_file(&sp).ok();
    assert_eq!(ck_par.buffers.len(), ck_seq.buffers.len(), "{label}: buffer count");
    for ((na, ba), (nb, bb)) in ck_par.buffers.iter().zip(&ck_seq.buffers) {
        assert_eq!(na, nb, "{label}: buffer order");
        // the clock buffer holds measured compute seconds (wall-clock,
        // legitimately different between modes); everything else —
        // params, optimizer state, RNG streams — must match exactly
        if na == "trainer.clock" {
            continue;
        }
        let same = ba.len() == bb.len()
            && ba.iter().zip(bb).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same, "{label}: buffer `{na}` differs between parallel and sequential");
    }
}

fn assert_parallel_equals_sequential(cfg: RunConfig) {
    assert_parallel_equals_sequential_on(cfg, backend());
}

#[test]
fn parallel_fleet_matches_sequential_for_every_outer_optimizer() {
    for outer in [
        OuterConfig::sign_momentum_paper(1.0),
        OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
        OuterConfig::SignedSlowMo { eta: 0.01, beta: 0.5 },
        OuterConfig::Lookahead { eta: 1.0, beta: 0.2, signed: false },
        OuterConfig::Lookahead { eta: 1.0, beta: 0.2, signed: true },
        OuterConfig::GlobalAdamW {
            eta: 1e-3,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.1,
        },
        OuterConfig::LocalAvg,
        OuterConfig::MvSignSgd { eta: 1e-3, beta: 0.9, alpha: 0.1, bound: 50.0 },
    ] {
        let mut cfg = base_cfg(&format!("pf-{}", outer.name()));
        cfg.outer = outer;
        assert_parallel_equals_sequential(cfg);
    }
}

#[test]
fn parallel_fleet_matches_sequential_across_worker_counts() {
    for n in [1usize, 2, 3, 8] {
        let mut cfg = base_cfg(&format!("pf-n{n}"));
        cfg.n_workers = n;
        assert_parallel_equals_sequential(cfg);
    }
}

#[test]
fn parallel_fleet_matches_sequential_in_standalone_mode() {
    let mut cfg = base_cfg("pf-standalone");
    cfg.mode = TrainMode::Standalone;
    cfg.tau = 1;
    cfg.rounds = 8;
    assert_parallel_equals_sequential(cfg);
}

#[test]
fn parallel_fleet_matches_sequential_on_heterogeneous_shards() {
    let mut cfg = base_cfg("pf-hetero");
    cfg.heterogeneous = true;
    assert_parallel_equals_sequential(cfg);
}

#[test]
fn parallel_fleet_matches_sequential_on_q8_wire() {
    // the quantized wire format under parallel local phases (which also
    // covers the pooled-vs-serial eval pass: `sequential_workers` gates
    // both, and the log rows compare val losses bit-for-bit)
    let mut cfg = base_cfg("pf-q8");
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8);
    assert_parallel_equals_sequential(cfg);
}

#[test]
fn q8_wire_runs_end_to_end_and_undercuts_dense_comm_time() {
    // the same Algorithm-1 run under both dense-method wire formats:
    // the q8 exchange must (a) train without diverging, (b) actually
    // quantize (trajectory differs from dense), and (c) bill less
    // modeled comm time at the default fleet size, per the
    // gather+broadcast-vs-ring analysis in dist/wire.rs
    let mut dense = base_cfg("pf-wire-dense");
    dense.rounds = 5;
    let mut q8 = dense.clone();
    q8.tag = "pf-wire-q8".into();
    q8.wire = Some(dsm::dist::WireFormat::QuantizedI8);
    let rd = run_cfg(dense);
    let rq = run_cfg(q8);

    let uniform = (256f64).ln();
    assert!(rq.final_val.is_finite());
    assert!(rq.final_val < uniform + 0.5, "q8 run diverged: {}", rq.final_val);
    assert_ne!(
        rd.final_val.to_bits(),
        rq.final_val.to_bits(),
        "q8 must actually quantize the exchange"
    );
    assert_eq!(rd.clock.comm_rounds, rq.clock.comm_rounds);
    assert!(
        rq.clock.comm_s < rd.clock.comm_s,
        "q8 comm {} vs dense {}",
        rq.clock.comm_s,
        rd.clock.comm_s
    );
}

#[test]
fn q8_wire_bills_exact_payload_bytes() {
    // gather+broadcast moves 2(n-1) copies of the (P + 12)-byte
    // quantized message per round — the clock must bill exactly that
    let mut cfg = base_cfg("pf-q8-bytes");
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8);
    cfg.eval_every = 0;
    let n = cfg.n_workers as u64;
    let rounds = cfg.rounds as u64;
    let mut t = Trainer::with_backend(cfg, backend()).unwrap();
    let p = t.dim();
    let res = t.run().unwrap();
    let payload = dsm::dist::WireFormat::QuantizedI8.wire_bytes(p, 1);
    assert_eq!(payload, p as u64 + 12);
    assert_eq!(res.clock.comm_rounds, rounds);
    assert_eq!(res.clock.bytes_communicated, rounds * payload * 2 * (n - 1));
}

#[test]
fn q8pt_wire_bills_exact_per_tensor_payload_bytes() {
    // the per-tensor message additionally carries one f32 scale per
    // layout segment: P + 8 + 4S bytes, moved 2(n-1) times per round —
    // on both native backends (2-segment MLP, 15-segment transformer)
    let cases = [(backend(), "pf-q8pt-bytes-mlp"), (transformer_backend(), "pf-q8pt-bytes-tf")];
    for (be, tag) in cases {
        let segments = be.layout().len() as u64;
        let mut cfg = base_cfg(tag);
        cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8PerTensor);
        cfg.eval_every = 0;
        let n = cfg.n_workers as u64;
        let rounds = cfg.rounds as u64;
        let mut t = Trainer::with_backend(cfg, be).unwrap();
        let p = t.dim();
        let res = t.run().unwrap();
        let payload =
            dsm::dist::WireFormat::QuantizedI8PerTensor.wire_bytes(p, segments as usize);
        assert_eq!(payload, p as u64 + 8 + 4 * segments, "{tag}");
        assert_eq!(res.clock.comm_rounds, rounds, "{tag}");
        assert_eq!(res.clock.bytes_communicated, rounds * payload * 2 * (n - 1), "{tag}");
    }
}

#[test]
fn parallel_fleet_matches_sequential_on_q8pt_wire() {
    let mut cfg = base_cfg("pf-q8pt");
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8PerTensor);
    assert_parallel_equals_sequential(cfg);
}

#[test]
fn parallel_fleet_matches_sequential_on_the_transformer_backend() {
    // the multi-layer preset through the same bit-identity matrix:
    // the paper's outer method, the vote path, and the layout-aware
    // wire all run on the transformer's 15-segment layout
    for (outer, wire, tag) in [
        (OuterConfig::sign_momentum_paper(1.0), None, "pf-tf-sign_momentum"),
        (
            OuterConfig::MvSignSgd { eta: 1e-3, beta: 0.9, alpha: 0.1, bound: 50.0 },
            None,
            "pf-tf-mv",
        ),
        (
            OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
            Some(dsm::dist::WireFormat::QuantizedI8PerTensor),
            "pf-tf-q8pt",
        ),
    ] {
        let mut cfg = base_cfg(tag);
        cfg.outer = outer;
        cfg.wire = wire;
        assert_parallel_equals_sequential_on(cfg, transformer_backend());
    }
}

#[test]
fn q8pt_actually_quantizes_per_segment() {
    // same run under q8 and q8pt: on a multi-segment layout the
    // per-segment scales decode differently, so the trajectories must
    // split — while both stay finite and trained
    let mut q8 = base_cfg("pf-q8-vs-q8pt-a");
    q8.rounds = 5;
    q8.wire = Some(dsm::dist::WireFormat::QuantizedI8);
    let mut q8pt = q8.clone();
    q8pt.tag = "pf-q8-vs-q8pt-b".into();
    q8pt.wire = Some(dsm::dist::WireFormat::QuantizedI8PerTensor);
    let ra = run_cfg(q8);
    let rb = run_cfg(q8pt);
    let uniform = (256f64).ln();
    assert!(rb.final_val.is_finite() && rb.final_val < uniform + 0.5, "{}", rb.final_val);
    assert_ne!(
        ra.final_val.to_bits(),
        rb.final_val.to_bits(),
        "per-tensor scales must change the decoded exchange on a 2-segment layout"
    );
    // same coordinate count, 1 extra scale on the wire
    assert_eq!(ra.clock.comm_rounds, rb.clock.comm_rounds);
    assert_eq!(
        rb.clock.bytes_communicated - ra.clock.bytes_communicated,
        // 4 bytes per extra scale × 2(n-1) messages × rounds
        4u64 * 2 * (4 - 1) * 5,
        "{} vs {}",
        ra.clock.bytes_communicated,
        rb.clock.bytes_communicated
    );
    // the per-round segment norms surfaced to the experiments name the
    // MLP layout's two segments
    let names: Vec<&str> = rb.segment_norms.iter().map(|n| n.name.as_str()).collect();
    assert_eq!(names, vec!["native.embed", "native.out"]);
}

#[test]
fn transformer_checkpoint_resume_is_bit_identical_under_q8pt() {
    // the full stack at once: multi-layer backend, layout-aware wire,
    // checkpoint in the middle — the resumed tail must replay the
    // uninterrupted run bit for bit
    let mut cfg = base_cfg("pf-tf-resume");
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8PerTensor);
    cfg.rounds = 6;
    cfg.eval_every = 0;
    let full = run_cfg_on(cfg.clone(), transformer_backend());

    let mut cfg_half = cfg.clone();
    cfg_half.rounds = 3;
    let mut t1 = Trainer::with_backend(cfg_half, transformer_backend()).unwrap();
    t1.run().unwrap();
    let path = std::env::temp_dir().join("dsm_pf_tf_q8pt_resume.ckpt");
    t1.save_checkpoint(&path).unwrap();

    let mut t2 = Trainer::with_backend(cfg, transformer_backend()).unwrap();
    t2.load_checkpoint(&path).unwrap();
    let resumed = t2.run().unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(resumed.final_val.to_bits(), full.final_val.to_bits());
    assert_eq!(resumed.clock.comm_rounds, full.clock.comm_rounds);
    assert_eq!(resumed.clock.bytes_communicated, full.clock.bytes_communicated);
    for (a, b) in resumed.segment_norms.iter().zip(&full.segment_norms) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.l2.to_bits(), b.l2.to_bits(), "segment {}", a.name);
    }
}

#[test]
fn clock_checkpoint_resumes_the_simulated_time_axis() {
    // ROADMAP (f): the SimClock rides in the checkpoint, so a resumed
    // run continues simulated time instead of restarting at zero
    let mut cfg = base_cfg("pf-clock");
    cfg.rounds = 6;
    cfg.eval_every = 0;
    cfg.comm = dsm::comm::CommModel::preset("wan").unwrap(); // stragglers on
    let full = run_cfg(cfg.clone());

    let mut cfg_half = cfg.clone();
    cfg_half.rounds = 3;
    let mut t1 = Trainer::with_backend(cfg_half, backend()).unwrap();
    t1.run().unwrap();
    let saved_compute = t1.clock().compute_s;
    let saved_comm = t1.clock().comm_s;
    assert!(saved_comm > 0.0, "three rounds must have charged comm time");
    let path = std::env::temp_dir().join("dsm_pf_clock_resume.ckpt");
    t1.save_checkpoint(&path).unwrap();

    let mut t2 = Trainer::with_backend(cfg, backend()).unwrap();
    t2.load_checkpoint(&path).unwrap();
    // the time axis resumes in place, not at zero
    assert_eq!(t2.clock().comm_s.to_bits(), saved_comm.to_bits());
    assert_eq!(t2.clock().compute_s.to_bits(), saved_compute.to_bits());
    let resumed = t2.run().unwrap();
    std::fs::remove_file(&path).ok();

    // modeled charges are deterministic (straggler draws replay from
    // the checkpointed fault stream): resumed ≡ uninterrupted, bit-level
    assert_eq!(resumed.clock.comm_s.to_bits(), full.clock.comm_s.to_bits());
    assert_eq!(resumed.clock.straggler_s.to_bits(), full.clock.straggler_s.to_bits());
    assert_eq!(resumed.clock.comm_rounds, full.clock.comm_rounds);
    assert_eq!(resumed.clock.bytes_communicated, full.clock.bytes_communicated);
    // measured compute is wall-clock, but it must accumulate on top of
    // the checkpointed value rather than restarting from zero
    assert!(resumed.clock.compute_s > saved_compute);
    // and the loss trajectory still replays exactly
    assert_eq!(resumed.final_val.to_bits(), full.final_val.to_bits());
}

#[test]
fn pre_clock_checkpoints_still_load() {
    // forward compatibility: a checkpoint without trainer.clock loads
    // fine and restarts the time axis at zero
    let cfg = base_cfg("pf-oldckpt");
    let mut t1 = Trainer::with_backend(cfg.clone(), backend()).unwrap();
    t1.run().unwrap();
    let path = std::env::temp_dir().join("dsm_pf_old_clock.ckpt");
    t1.save_checkpoint(&path).unwrap();
    let mut ck = dsm::train::checkpoint::Checkpoint::load(&path).unwrap();
    ck.buffers.retain(|(name, _)| name != "trainer.clock");
    ck.save(&path).unwrap();

    let mut t2 = Trainer::with_backend(cfg, backend()).unwrap();
    t2.load_checkpoint(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(t2.clock().comm_s, 0.0);
    assert_eq!(t2.clock().comm_rounds, 0);
}

#[test]
fn divergence_still_fails_loudly_under_parallel_execution() {
    let mut cfg = base_cfg("pf-diverge");
    cfg.schedule = dsm::train::schedule::ScheduleConfig::Constant { lr: 1e9 };
    let mut t = Trainer::with_backend(cfg, backend()).unwrap();
    let err = t.run();
    assert!(err.is_err(), "expected a divergence error from the fleet");
}

#[test]
fn deterministic_across_repeated_parallel_runs() {
    // scheduling nondeterminism must never leak into results: the same
    // parallel config twice is bit-identical
    let a = run_cfg(base_cfg("pf-repeat"));
    let b = run_cfg(base_cfg("pf-repeat"));
    assert_eq!(a.final_val.to_bits(), b.final_val.to_bits());
    for (ra, rb) in a.log.rows.iter().zip(&b.log.rows) {
        assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits());
        assert_eq!(ra.val_loss.to_bits(), rb.val_loss.to_bits());
    }
}
