//! Property-based tests over randomized inputs (in-tree substitute for
//! proptest, which is unavailable offline): each property draws many
//! random cases from a seeded generator and asserts an invariant; on
//! failure the seed + case index pinpoint the reproduction.

use dsm::data::corpus::{generate, CorpusConfig};
use dsm::data::dataset::TokenDataset;
use dsm::data::{Bpe, ByteTokenizer, Tokenizer};
use dsm::dist::Worker;
use dsm::optim::BaseOptConfig;
use dsm::outer::{run_synthetic_round, OuterConfig};
use dsm::sign::SignOp;
use dsm::tensor;
use dsm::train::checkpoint::Checkpoint;
use dsm::train::schedule::ScheduleConfig;
use dsm::util::json::Json;
use dsm::util::rng::Rng;

/// Mini property harness: run `f` on `cases` random inputs.
fn forall<F: FnMut(u64, &mut Rng)>(name: &str, cases: u64, mut f: F) {
    for case in 0..cases {
        let mut rng = Rng::new(0xD5A1 ^ case);
        f(case, &mut rng);
    }
    let _ = name;
}

#[test]
fn prop_outer_rounds_preserve_finiteness_and_dimension() {
    forall("outer-finite", 40, |case, rng| {
        let d = 1 + rng.below(200) as usize;
        let configs = [
            OuterConfig::SignMomentum {
                eta: rng.f32() * 2.0,
                beta1: rng.f32() * 0.99,
                beta2: rng.f32() * 0.99,
                weight_decay: rng.f32() * 0.2,
                sign_op: *rng.choose(&[SignOp::Exact, SignOp::RandPm, SignOp::RandZero]),
                sign_bound: 100.0,
            },
            OuterConfig::SlowMo { alpha: rng.f32() * 2.0, beta: rng.f32() * 0.99 },
            OuterConfig::SignedSlowMo { eta: rng.f32() * 2.0, beta: rng.f32() * 0.99 },
            OuterConfig::GlobalAdamW {
                eta: rng.f32(),
                beta1: 0.9,
                beta2: 0.95,
                eps: 1e-8,
                weight_decay: 0.1,
            },
            OuterConfig::LocalAvg,
        ];
        let cfg = rng.choose(&configs).clone();
        let mut opt = cfg.build(d);
        let mut x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for round in 0..8 {
            let diff: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 0.05)).collect();
            let gamma = 1e-4 + rng.f32() * 0.5;
            run_synthetic_round(opt.as_mut(), &mut x, &diff, gamma, round);
            assert_eq!(x.len(), d);
            assert!(tensor::all_finite(&x), "case {case}: {} produced non-finite", cfg.name());
        }
    });
}

#[test]
fn prop_sign_ops_are_ternary_and_exact_dominates_magnitude() {
    forall("sign-ternary", 60, |case, rng| {
        let d = 1 + rng.below(500) as usize;
        let bound = 1.0 + rng.f32() * 100.0;
        let v: Vec<f32> =
            (0..d).map(|_| (rng.f32() * 2.0 - 1.0) * bound * 0.999).collect();
        for op in [SignOp::Exact, SignOp::RandPm, SignOp::RandZero] {
            let out = op.apply(&v, bound, rng);
            for (j, (&o, &x)) in out.iter().zip(&v).enumerate() {
                assert!(o == 0.0 || o == 1.0 || o == -1.0, "case {case} coord {j}");
                // randomized-zero never flips the sign; ±-flip may, exact never
                if op == SignOp::RandZero && o != 0.0 {
                    assert_eq!(o, tensor::sign_f32(x));
                }
                if op == SignOp::Exact {
                    assert_eq!(o, tensor::sign_f32(x));
                }
            }
        }
    });
}

#[test]
fn prop_bpe_roundtrips_arbitrary_bytes() {
    let corpus = generate(&CorpusConfig { bytes: 40_000, ..Default::default() });
    let bpe = Bpe::train(&corpus, 300 + 64);
    forall("bpe-roundtrip", 30, |case, rng| {
        let len = rng.below(2000) as usize;
        let text: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let enc = bpe.encode(&text);
        assert_eq!(bpe.decode(&enc), text, "case {case}");
        assert!(enc.len() <= text.len(), "BPE must never expand");
    });
}

#[test]
fn prop_json_roundtrips_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bernoulli(0.5)),
            2 => Json::Num((rng.range(-1_000_000, 1_000_000) as f64) / 64.0),
            3 => Json::Str(
                (0..rng.below(20)).map(|_| rng.choose(&['a', 'β', '"', '\\', '\n', ' ', '7']))
                    .collect::<String>(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall("json-roundtrip", 60, |case, rng| {
        let v = random_json(rng, 0);
        let text = v.to_string_compact();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, v, "case {case}: {text}");
    });
}

#[test]
fn prop_checkpoint_roundtrips_random_buffers() {
    let dir = std::env::temp_dir().join("dsm_prop_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    forall("ckpt-roundtrip", 15, |case, rng| {
        let mut ck = Checkpoint::new(&format!("prop-{case}"), rng.below(1000));
        let n_bufs = 1 + rng.below(6) as usize;
        for i in 0..n_bufs {
            let len = rng.below(4000) as usize;
            let buf: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 10.0)).collect();
            ck.add(&format!("buf{i}"), &buf);
        }
        let path = dir.join(format!("{case}.ckpt"));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.buffers.len(), ck.buffers.len());
        for ((na, ba), (nb, bb)) in ck.buffers.iter().zip(&back.buffers) {
            assert_eq!(na, nb);
            assert_eq!(ba, bb, "case {case}: buffer {na} bits changed");
        }
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_schedule_is_positive_bounded_and_warmup_monotone() {
    forall("schedule", 40, |case, rng| {
        let peak = 10f32.powf(-(1.0 + rng.f32() * 4.0));
        let total = 10 + rng.below(100_000);
        let cfg = ScheduleConfig::cosine_paper(peak, total);
        let s = cfg.build();
        let warmup = match cfg {
            ScheduleConfig::Cosine { warmup, .. } => warmup,
            _ => unreachable!(),
        };
        let mut prev = 0.0f32;
        for t in 0..warmup {
            let lr = s.lr(t);
            assert!(lr > prev || t == 0, "case {case}: warmup not increasing at {t}");
            prev = lr;
        }
        for t in (0..total + 100).step_by((total as usize / 50).max(1)) {
            let lr = s.lr(t);
            assert!(lr > 0.0 && lr <= peak * 1.0001, "case {case}: lr {lr} out of range at {t}");
        }
    });
}

#[test]
fn prop_dataset_shards_partition_and_targets_shift() {
    forall("dataset", 20, |case, rng| {
        let len = 2_000 + rng.below(20_000) as usize;
        let tokens: Vec<u32> = (0..len).map(|_| rng.below(256) as u32).collect();
        let ds = TokenDataset::from_tokens(tokens, 0.1);
        let n = 1 + rng.below(7) as usize;
        let mut covered = 0;
        for w in 0..n {
            let (lo, hi) = ds.shard_range(w, n);
            assert_eq!(lo, covered, "case {case}");
            covered = hi;
        }
        assert_eq!(covered, ds.train_len());
        let seq = 16 + 8 * rng.below(4) as usize;
        if ds.shard_range(0, n).1 > seq + 2 {
            let b = ds.sample_train(0, n, 2, seq, rng);
            for i in 0..2 {
                for j in 0..seq - 1 {
                    assert_eq!(b.tokens[i * seq + j + 1], b.targets[i * seq + j]);
                }
            }
        }
    });
}

#[test]
fn prop_allreduce_mean_bounds_and_permutation_invariance() {
    forall("allreduce", 30, |case, rng| {
        let d = 1 + rng.below(100) as usize;
        let n = 1 + rng.below(8) as usize;
        let mut workers: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 5.0)).collect())
            .collect();
        let mut out = vec![0.0f32; d];
        dsm::dist::collectives::allreduce_mean(&workers, |w| w.as_slice(), &mut out);
        for j in 0..d {
            let lo = workers.iter().map(|w| w[j]).fold(f32::MAX, f32::min);
            let hi = workers.iter().map(|w| w[j]).fold(f32::MIN, f32::max);
            assert!(out[j] >= lo - 1e-4 && out[j] <= hi + 1e-4, "case {case}");
        }
        rng.shuffle(&mut workers);
        let mut out2 = vec![0.0f32; d];
        dsm::dist::collectives::allreduce_mean(&workers, |w| w.as_slice(), &mut out2);
        assert!(tensor::max_abs_diff(&out, &out2) < 1e-5, "case {case}");
    });
}

/// Seed determinism across the dist::Worker substream plumbing the
/// trainer relies on: two fleets built from the same root `Rng` must
/// produce bit-identical parameters after identical observe/step
/// sequences, while distinct ranks draw distinct data streams.
#[test]
fn prop_worker_fleets_from_same_root_rng_are_identical() {
    forall("worker-determinism", 12, |case, rng| {
        let p = 4 + rng.below(200) as usize;
        let n = 1 + rng.below(6) as usize;
        let seed = rng.next_u64();
        let base = rng
            .choose(&[
                BaseOptConfig::sgd_plain(),
                BaseOptConfig::Sgd { momentum: 0.9, nesterov: true, weight_decay: 0.01 },
                BaseOptConfig::adamw_paper(),
                BaseOptConfig::lion_paper(),
            ])
            .clone();
        let root_a = Rng::new(seed);
        let root_b = Rng::new(seed);
        let layout = std::sync::Arc::new(dsm::runtime::ParamLayout::single(p));
        let mut fleet_a: Vec<Worker> =
            (0..n).map(|i| Worker::new(i, layout.clone(), &base, &root_a)).collect();
        let mut fleet_b: Vec<Worker> =
            (0..n).map(|i| Worker::new(i, layout.clone(), &base, &root_b)).collect();

        for step in 0..5 {
            for w in 0..n {
                // each worker synthesizes its "gradient" from its own
                // substream — exactly how the trainer's data sampling
                // consumes worker RNGs
                let mut ga = vec![0.0f32; p];
                let mut gb = vec![0.0f32; p];
                fleet_a[w].rng.fill_normal(&mut ga, 0.5);
                fleet_b[w].rng.fill_normal(&mut gb, 0.5);
                assert_eq!(ga, gb, "case {case}: substreams diverged at step {step}");
                let lr = 1e-2 / (1.0 + step as f32);
                let wa = &mut fleet_a[w];
                wa.observe(1.5, &ga);
                wa.opt.step(&mut wa.params, &ga, lr);
                let wb = &mut fleet_b[w];
                wb.observe(1.5, &gb);
                wb.opt.step(&mut wb.params, &gb, lr);
            }
        }

        for (wa, wb) in fleet_a.iter_mut().zip(fleet_b.iter_mut()) {
            assert_eq!(wa.params, wb.params, "case {case}: worker {} params", wa.id);
            assert_eq!(wa.last_grad, wb.last_grad, "case {case}: worker {}", wa.id);
            let (la, lb) = (wa.take_mean_loss(), wb.take_mean_loss());
            assert_eq!(la.to_bits(), lb.to_bits(), "case {case}: worker {}", wa.id);
        }
        if n >= 2 {
            assert_ne!(
                fleet_a[0].params, fleet_a[1].params,
                "case {case}: distinct ranks must see distinct data"
            );
        }
    });
}

#[test]
fn prop_byte_tokenizer_is_total_bijection() {
    forall("byte-tok", 20, |_case, rng| {
        let len = rng.below(4096) as usize;
        let text: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let t = ByteTokenizer;
        assert_eq!(t.decode(&t.encode(&text)), text);
    });
}
