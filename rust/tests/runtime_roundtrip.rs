//! Cross-language integration: the AOT'd HLO artifacts executed through
//! PJRT from Rust must reproduce the jax-side semantics.
//!
//! Requires `make artifacts` (tests self-skip when artifacts are absent,
//! mirroring the pytest suite's skip behaviour).

use dsm::data::corpus::{generate, CorpusConfig};
use dsm::data::dataset::TokenDataset;
use dsm::data::ByteTokenizer;
use dsm::outer::{run_synthetic_round, SignMomentum};
use dsm::runtime::{Artifacts, ModelBundle, Runtime, SignUpdateKernel, SignUpdateScalars};
use dsm::sign::SignOp;
use dsm::tensor;
use dsm::util::rng::Rng;

fn setup() -> Option<(Runtime, Artifacts)> {
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some((Runtime::cpu().unwrap(), Artifacts::load(&dir).unwrap()))
}

fn nano_bundle(rt: &Runtime, arts: &Artifacts) -> ModelBundle {
    ModelBundle::load(rt, arts.preset("nano").unwrap()).unwrap()
}

fn batch(bundle: &ModelBundle, seed: u64) -> dsm::data::dataset::Batch {
    let corpus = generate(&CorpusConfig { bytes: 1 << 18, seed, ..Default::default() });
    let ds = TokenDataset::from_text(&ByteTokenizer, &corpus, 0.1);
    let mut rng = Rng::new(seed);
    ds.sample_train(0, 1, bundle.info.batch, bundle.info.seq, &mut rng)
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some((rt, arts)) = setup() else { return };
    let bundle = nano_bundle(&rt, &arts);
    let a = bundle.init_params(7).unwrap();
    let b = bundle.init_params(7).unwrap();
    let c = bundle.init_params(8).unwrap();
    assert_eq!(a, b);
    assert!(tensor::max_abs_diff(&a, &c) > 1e-3);
    assert_eq!(a.len(), bundle.info.param_count);
    // GPT-2 init statistics survive the trip: embeddings ~N(0, 0.02)
    let wte = arts.preset("nano").unwrap().layout.iter().find(|e| e.name == "wte").unwrap();
    let emb = &a[wte.offset..wte.offset + wte.numel()];
    let std = (tensor::norm2_sq(emb) / emb.len() as f64).sqrt();
    assert!((std - 0.02).abs() < 0.003, "wte std {std}");
}

#[test]
fn initial_loss_is_near_uniform_and_grads_flow() {
    let Some((rt, arts)) = setup() else { return };
    let bundle = nano_bundle(&rt, &arts);
    let params = bundle.init_params(42).unwrap();
    let b = batch(&bundle, 1);
    let out = bundle.train_step(&params, &b).unwrap();
    // ln(256) = 5.545; GPT-2 init is near-uniform over the vocab
    assert!((out.loss - 5.545).abs() < 0.3, "loss {}", out.loss);
    assert!(tensor::all_finite(&out.grads));
    assert!(tensor::norm2(&out.grads) > 1e-3);
    // eval artifact agrees with the train artifact's loss
    let eval = bundle.eval_loss(&params, &b).unwrap();
    assert!((eval - out.loss).abs() < 1e-4, "{eval} vs {}", out.loss);
}

#[test]
fn gradients_match_finite_differences() {
    let Some((rt, arts)) = setup() else { return };
    let bundle = nano_bundle(&rt, &arts);
    let mut params = bundle.init_params(3).unwrap();
    let b = batch(&bundle, 2);
    let out = bundle.train_step(&params, &b).unwrap();
    // probe a few well-spread coordinates with central differences
    let p = params.len();
    for &idx in &[10usize, p / 3, p / 2 + 17, p - 5] {
        let h = 2e-2f32; // f32 eval noise ~1e-4 on the loss; need a big h
        let orig = params[idx];
        params[idx] = orig + h;
        let lp = bundle.eval_loss(&params, &b).unwrap();
        params[idx] = orig - h;
        let lm = bundle.eval_loss(&params, &b).unwrap();
        params[idx] = orig;
        let fd = (lp - lm) / (2.0 * h);
        let ad = out.grads[idx];
        assert!(
            (fd - ad).abs() < 2e-2_f32.max(0.2 * ad.abs()),
            "coord {idx}: fd {fd} vs autodiff {ad}"
        );
    }
}

#[test]
fn one_round_of_training_reduces_loss() {
    let Some((rt, arts)) = setup() else { return };
    let bundle = nano_bundle(&rt, &arts);
    let mut params = bundle.init_params(5).unwrap();
    let b = batch(&bundle, 3);
    let before = bundle.eval_loss(&params, &b).unwrap();
    for _ in 0..3 {
        let out = bundle.train_step(&params, &b).unwrap();
        tensor::axpy(&mut params, -0.05, &out.grads);
    }
    let after = bundle.eval_loss(&params, &b).unwrap();
    assert!(after < before, "{before} -> {after}");
}

/// Three-way equivalence: the AOT'd Pallas sign-update kernel == the
/// native Rust Algorithm-1 implementation (both already pinned to the
/// jnp oracle on the python side).
#[test]
fn pallas_kernel_matches_rust_sign_momentum() {
    let Some((rt, arts)) = setup() else { return };
    let kernel = SignUpdateKernel::load(&rt, &arts).unwrap();
    // deliberately NOT a multiple of the chunk size: exercises padding
    let p = arts.sign_update_chunk + 12_345;
    let mut rng = Rng::new(17);
    let mut x = vec![0.0f32; p];
    let mut m = vec![0.0f32; p];
    let mut diff_applied = vec![0.0f32; p];
    rng.fill_normal(&mut x, 0.05);
    rng.fill_normal(&mut m, 0.3);
    rng.fill_normal(&mut diff_applied, 0.002);
    let gamma = 3e-3f32;

    // native Rust path
    let mut rust_opt = SignMomentum::new(p, 1.2, 0.95, 0.98, 0.1, SignOp::Exact, 1.0);
    rust_opt.load_state(&[m.clone()]);
    let mut x_rust = x.clone();
    run_synthetic_round(&mut rust_opt, &mut x_rust, &diff_applied, gamma, 0);

    // Pallas kernel path
    let mut x_pallas = x.clone();
    let mut m_pallas = m.clone();
    kernel
        .apply(
            &mut x_pallas,
            &mut m_pallas,
            &diff_applied,
            SignUpdateScalars { gamma, eta: 1.2, weight_decay: 0.1, beta1: 0.95, beta2: 0.98 },
        )
        .unwrap();

    assert!(
        tensor::max_abs_diff(&x_rust, &x_pallas) < 1e-5,
        "x diverged: {}",
        tensor::max_abs_diff(&x_rust, &x_pallas)
    );
    let m_rust = rust_opt.state()[0].to_vec();
    // m update involves diff/gamma ~ O(1); allow f32 rounding
    assert!(tensor::max_abs_diff(&m_rust, &m_pallas) < 1e-3);
}

use dsm::outer::OuterOptimizer; // for load_state/state on SignMomentum
