//! End-to-end trainer integration over the real PJRT runtime (nano).
//! Requires `make artifacts`; tests self-skip otherwise.

use std::sync::Arc;

use dsm::config::{RunConfig, TrainMode};
use dsm::outer::OuterConfig;
use dsm::runtime::{Artifacts, ModelBundle, Runtime};
use dsm::train::Trainer;

struct Env {
    rt: Runtime,
    arts: Artifacts,
    bundle: Arc<ModelBundle>,
}

fn setup() -> Option<Env> {
    let dir = Artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let rt = Runtime::cpu().unwrap();
    let arts = Artifacts::load(&dir).unwrap();
    let bundle = Arc::new(ModelBundle::load(&rt, arts.preset("nano").unwrap()).unwrap());
    Some(Env { rt, arts, bundle })
}

fn tiny_cfg(tag: &str) -> RunConfig {
    let mut cfg = RunConfig::paper_default("nano");
    cfg.rounds = 4;
    cfg.tau = 4;
    cfg.n_workers = 2;
    cfg.corpus_bytes = 1 << 18;
    cfg.eval_every = 2;
    cfg.eval_batches = 2;
    cfg.tag = tag.to_string();
    cfg
}

fn run(env: &Env, cfg: RunConfig) -> dsm::train::RunResult {
    let mut t = Trainer::with_bundle(cfg, env.bundle.clone(), &env.rt, &env.arts).unwrap();
    t.run().unwrap()
}

#[test]
fn every_outer_optimizer_trains_and_reduces_loss() {
    let Some(env) = setup() else { return };
    let uniform = (256f64).ln();
    for outer in [
        OuterConfig::sign_momentum_paper(12.0),
        OuterConfig::SlowMo { alpha: 1.0, beta: 0.5 },
        OuterConfig::SignedSlowMo { eta: 0.01, beta: 0.5 },
        OuterConfig::Lookahead { eta: 1.0, beta: 0.2, signed: false },
        OuterConfig::GlobalAdamW {
            eta: 1e-3,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.1,
        },
        OuterConfig::LocalAvg,
        OuterConfig::MvSignSgd { eta: 1e-3, beta: 0.9, alpha: 0.1, bound: 50.0 },
    ] {
        let mut cfg = tiny_cfg(&format!("it-{}", outer.name()));
        cfg.outer = outer.clone();
        let res = run(&env, cfg);
        if outer.name() == "mv_signsgd" {
            // MV's randomized 1-bit votes are near-coin-flips when
            // |m| << B (Remark 2's neighborhood): at 4 rounds we only
            // require that it does not blow up.
            assert!(
                res.final_val < uniform + 0.1,
                "mv_signsgd diverged: {}",
                res.final_val
            );
        } else {
            assert!(
                res.final_val < uniform,
                "{}: {} not below uniform {uniform}",
                outer.name(),
                res.final_val
            );
        }
    }
}

#[test]
fn standalone_mode_trains() {
    let Some(env) = setup() else { return };
    let mut cfg = tiny_cfg("it-standalone");
    cfg.mode = TrainMode::Standalone;
    cfg.tau = 1;
    cfg.rounds = 16;
    let res = run(&env, cfg);
    assert!(res.final_val < (256f64).ln());
    // standalone communicates every computation round
    assert_eq!(res.clock.comm_rounds, 16);
}

#[test]
fn runs_are_deterministic_given_seed() {
    let Some(env) = setup() else { return };
    let a = run(&env, tiny_cfg("det"));
    let b = run(&env, tiny_cfg("det"));
    assert_eq!(a.final_val, b.final_val);
    assert_eq!(a.log.rows.len(), b.log.rows.len());
    for (ra, rb) in a.log.rows.iter().zip(&b.log.rows) {
        assert_eq!(ra.train_loss, rb.train_loss);
        assert_eq!(ra.val_loss.to_bits(), rb.val_loss.to_bits());
    }
    let mut cfg = tiny_cfg("det");
    cfg.seed += 1;
    let c = run(&env, cfg);
    assert_ne!(a.final_val, c.final_val);
}

#[test]
fn sim_clock_accounts_for_tau_communication_savings() {
    let Some(env) = setup() else { return };
    let mut a = tiny_cfg("clock-tau4");
    a.comm = dsm::comm::CommModel::preset("wan").unwrap();
    let mut b = a.clone();
    b.tau = 1;
    b.rounds = 16; // same 16 local steps
    b.tag = "clock-tau1".into();
    let ra = run(&env, a);
    let rb = run(&env, b);
    assert_eq!(ra.clock.comm_rounds * 4, rb.clock.comm_rounds);
    assert!(ra.clock.comm_s < rb.clock.comm_s / 2.0);
}

#[test]
fn checkpoint_resume_reproduces_uninterrupted_run() {
    let Some(env) = setup() else { return };
    // full run: 6 rounds
    let mut cfg = tiny_cfg("ck-full");
    cfg.rounds = 6;
    cfg.eval_every = 0;
    let full = run(&env, cfg.clone());

    // interrupted run: 3 rounds, checkpoint, resume to 6
    let mut cfg_a = cfg.clone();
    cfg_a.rounds = 3;
    let mut t1 =
        Trainer::with_bundle(cfg_a, env.bundle.clone(), &env.rt, &env.arts).unwrap();
    t1.run().unwrap();
    let path = std::env::temp_dir().join("dsm_it_resume.ckpt");
    t1.save_checkpoint(&path).unwrap();

    let mut t2 =
        Trainer::with_bundle(cfg.clone(), env.bundle.clone(), &env.rt, &env.arts).unwrap();
    t2.load_checkpoint(&path).unwrap();
    let resumed = t2.run().unwrap();
    std::fs::remove_file(&path).ok();

    // Worker and trainer RNG streams ride along in the checkpoint, so
    // the resumed tail replays the uninterrupted run bit-for-bit.
    assert_eq!(resumed.log.rows.last().unwrap().round, 6);
    assert_eq!(
        resumed.final_val.to_bits(),
        full.final_val.to_bits(),
        "resumed {} vs full {}",
        resumed.final_val,
        full.final_val
    );
}

#[test]
fn q8_wire_trains_end_to_end_on_the_real_runtime() {
    let Some(env) = setup() else { return };
    // the 8-bit quantized exchange for a dense-exchange method must
    // still learn (bounded rounding error in the exchanged differences)
    let mut cfg = tiny_cfg("q8-e2e");
    cfg.outer = OuterConfig::sign_momentum_paper(12.0);
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8);
    let res = run(&env, cfg);
    assert!(
        res.final_val < (256f64).ln(),
        "q8 sign_momentum should beat uniform: {}",
        res.final_val
    );
}

#[test]
fn q8pt_wire_trains_and_bills_the_manifest_layout_on_the_real_runtime() {
    let Some(env) = setup() else { return };
    // the layout-aware exchange resolves the REAL GPT-2 manifest layout
    // (wte, per-block attention/MLP tensors): it must learn, and the
    // clock must bill exactly P + 8 + 4S bytes per message
    let info = env.arts.preset("nano").unwrap();
    let segments = info.layout.len() as u64;
    assert!(segments > 1, "nano's manifest layout should be multi-tensor");
    let mut cfg = tiny_cfg("q8pt-e2e");
    cfg.outer = OuterConfig::sign_momentum_paper(12.0);
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8PerTensor);
    let n = cfg.n_workers as u64;
    let rounds = cfg.rounds as u64;
    let mut t = Trainer::with_bundle(cfg, env.bundle.clone(), &env.rt, &env.arts).unwrap();
    let p = t.dim();
    let res = t.run().unwrap();
    assert!(
        res.final_val < (256f64).ln(),
        "q8pt sign_momentum should beat uniform: {}",
        res.final_val
    );
    let payload = p as u64 + 8 + 4 * segments;
    assert_eq!(res.clock.bytes_communicated, rounds * payload * 2 * (n - 1));
    // the per-segment norms name the manifest's tensors
    assert_eq!(res.segment_norms.len(), segments as usize);
    assert!(res.segment_norms.iter().any(|s| s.name == "wte"));
}

#[test]
fn q8pt_checkpoint_resume_is_bit_identical_on_the_real_runtime() {
    let Some(env) = setup() else { return };
    let mut cfg = tiny_cfg("q8pt-ck");
    cfg.wire = Some(dsm::dist::WireFormat::QuantizedI8PerTensor);
    cfg.rounds = 6;
    cfg.eval_every = 0;
    let full = run(&env, cfg.clone());

    let mut cfg_half = cfg.clone();
    cfg_half.rounds = 3;
    let mut t1 =
        Trainer::with_bundle(cfg_half, env.bundle.clone(), &env.rt, &env.arts).unwrap();
    t1.run().unwrap();
    let path = std::env::temp_dir().join("dsm_it_q8pt_resume.ckpt");
    t1.save_checkpoint(&path).unwrap();

    let mut t2 =
        Trainer::with_bundle(cfg, env.bundle.clone(), &env.rt, &env.arts).unwrap();
    t2.load_checkpoint(&path).unwrap();
    let resumed = t2.run().unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(resumed.final_val.to_bits(), full.final_val.to_bits());
    assert_eq!(resumed.clock.bytes_communicated, full.clock.bytes_communicated);
}

#[test]
fn mv_checkpoint_resume_is_bit_identical() {
    let Some(env) = setup() else { return };
    let mut cfg = tiny_cfg("mv-ck");
    cfg.outer = OuterConfig::MvSignSgd { eta: 1e-3, beta: 0.9, alpha: 0.1, bound: 50.0 };
    cfg.rounds = 6;
    cfg.eval_every = 0;
    let full = run(&env, cfg.clone());

    let mut cfg_half = cfg.clone();
    cfg_half.rounds = 3;
    let mut t1 =
        Trainer::with_bundle(cfg_half, env.bundle.clone(), &env.rt, &env.arts).unwrap();
    t1.run().unwrap();
    let path = std::env::temp_dir().join("dsm_it_mv_resume.ckpt");
    t1.save_checkpoint(&path).unwrap();

    let mut t2 =
        Trainer::with_bundle(cfg, env.bundle.clone(), &env.rt, &env.arts).unwrap();
    t2.load_checkpoint(&path).unwrap();
    let resumed = t2.run().unwrap();
    std::fs::remove_file(&path).ok();

    // per-worker momentum, x_prev, every RNG stream, and the simulated
    // clock are restored, so the randomized sign votes of rounds 4-6
    // replay exactly and the time axis continues in place
    // (rust/tests/parallel_fleet.rs pins the clock equality natively)
    let (a, b) = (resumed.log.rows.last().unwrap(), full.log.rows.last().unwrap());
    assert_eq!(a.round, b.round);
    assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
    assert_eq!(resumed.final_val.to_bits(), full.final_val.to_bits());
}

#[test]
fn mv_packed_path_charges_exact_codec_bytes() {
    let Some(env) = setup() else { return };
    let mut cfg = tiny_cfg("mv-bytes");
    cfg.outer = OuterConfig::MvSignSgd { eta: 1e-3, beta: 0.9, alpha: 0.1, bound: 50.0 };
    let n = cfg.n_workers as u64;
    let rounds = cfg.rounds as u64;
    let mut t = Trainer::with_bundle(cfg, env.bundle.clone(), &env.rt, &env.arts).unwrap();
    let p = t.dim();
    let res = t.run().unwrap();
    // the clock must bill exactly the codec's packed payload — the same
    // bytes the PackedVotes buffers actually carry — per round, moved
    // through gather+broadcast's 2(n-1) messages (n-1 rank payloads up
    // to the server, the winner out to n-1 receivers)
    let payload = dsm::dist::codec::sign_allreduce_bytes(p);
    let moved_per_round = payload * 2 * (n - 1);
    assert_eq!(res.clock.comm_rounds, rounds);
    assert_eq!(res.clock.bytes_communicated, rounds * moved_per_round);
}

#[test]
fn pallas_global_step_matches_native_trainer() {
    let Some(env) = setup() else { return };
    let mut native = tiny_cfg("gs-native");
    native.outer = OuterConfig::sign_momentum_paper(6.0);
    let mut pallas = native.clone();
    pallas.tag = "gs-pallas".into();
    pallas.global_step_pallas = true;
    let rn = run(&env, native);
    let rp = run(&env, pallas);
    // identical data, identical updates modulo f32 associativity in the kernel
    assert!(
        (rn.final_val - rp.final_val).abs() < 5e-3,
        "native {} vs pallas {}",
        rn.final_val,
        rp.final_val
    );
}

#[test]
fn diverging_config_fails_loudly_not_silently() {
    let Some(env) = setup() else { return };
    let mut cfg = tiny_cfg("diverge");
    // absurd LR to force non-finite loss quickly
    cfg.schedule = dsm::train::schedule::ScheduleConfig::Constant { lr: 1e6 };
    let mut t = Trainer::with_bundle(cfg, env.bundle.clone(), &env.rt, &env.arts).unwrap();
    let err = t.run();
    assert!(err.is_err(), "expected divergence error");
}
