//! In-tree minimal substitute for the `anyhow` crate (crates.io is
//! unreachable in this build environment, so the workspace vendors the
//! exact surface it uses — nothing more):
//!
//! * [`Error`] — a message-chain error type; like the real `anyhow::Error`
//!   it deliberately does **not** implement `std::error::Error`, which is
//!   what makes the blanket `From<E: std::error::Error>` impl coherent.
//! * [`Result`] — `Result<T, Error>` with the `E` parameter defaulted.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on any
//!   `Result<T, E>` whose error converts into [`Error`].
//!
//! Formatting matches the shapes callers rely on: `{e}` prints the
//! outermost message, `{e:#}` prints the full chain joined by `": "`,
//! and `{e:?}` prints the anyhow-style "Caused by:" report.

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A chain of error messages, outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: std::fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: std::fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Coherent for the same reason the real anyhow's impl is: `Error` itself
// does not implement `std::error::Error`, so this blanket impl cannot
// overlap with the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to the error branch of a `Result`.
pub trait Context<T, E>: Sized {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: std::fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string (with inline captures), a
/// format string plus arguments, or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn msg_and_macro_forms() {
        let x = 3;
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("x = {x}").to_string(), "x = 3");
        assert_eq!(anyhow!("x = {}", x + 1).to_string(), "x = 4");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
        assert_eq!(Error::msg("direct").to_string(), "direct");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn context_chains_and_formats() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
        assert!(format!("{e:?}").contains("Caused by:"));
        assert_eq!(e.root_cause(), "missing");
        // context also applies to Result<_, Error>
        let r2: Result<()> = Err(e);
        let e2 = r2.context("loading run").unwrap_err();
        assert_eq!(e2.chain().count(), 3);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(v: usize) -> Result<usize> {
            ensure!(v > 1);
            ensure!(v > 2, "v too small: {v}");
            if v > 100 {
                bail!("v too big: {}", v);
            }
            Ok(v)
        }
        assert_eq!(f(0).unwrap_err().to_string(), "condition failed: `v > 1`");
        assert_eq!(f(2).unwrap_err().to_string(), "v too small: 2");
        assert_eq!(f(101).unwrap_err().to_string(), "v too big: 101");
        assert_eq!(f(3).unwrap(), 3);
    }
}
