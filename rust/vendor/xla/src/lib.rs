//! In-tree API stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! See README.md: host-side [`Literal`] plumbing is real; `compile` /
//! `execute` report the backend as unavailable. The surface mirrors
//! exactly what `dsm::runtime` consumes, so swapping in the real
//! bindings is a Cargo.toml-only change.

use std::path::Path;

/// Error type; the real crate's error also only promises `Debug` at the
/// `dsm` boundary (stringified by `runtime::anyhow_xla`).
#[derive(Clone)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Typed storage behind a [`Literal`]. Public only so the sealed
/// [`NativeType`] trait can name it; not part of the stable surface.
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized + 'static {
    #[doc(hidden)]
    const NAME: &'static str;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

macro_rules! native {
    ($ty:ty, $variant:ident, $name:literal) => {
        impl NativeType for $ty {
            const NAME: &'static str = $name;
            fn wrap(v: Vec<Self>) -> Data {
                Data::$variant(v)
            }
            fn unwrap(d: &Data) -> Option<Vec<Self>> {
                match d {
                    Data::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32, "f32");
native!(i32, I32, "i32");
native!(u32, U32, "u32");

/// A host-side typed array (or tuple of arrays) with a shape.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: Vec::new() }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        let n = parts.len() as i64;
        Literal { data: Data::Tuple(parts), dims: vec![n] }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Reinterpret the shape; the element count must be preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n != self.element_count() as i64 {
            return Err(Error::new(format!(
                "reshape: {} elements do not fit {:?}",
                self.element_count(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error::new(format!("literal does not hold {} elements", T::NAME)))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(t) => Ok(t),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        let mut t = self.to_tuple()?;
        if t.len() != 1 {
            return Err(Error::new(format!("expected a 1-tuple, got {} parts", t.len())));
        }
        Ok(t.pop().expect("length checked above"))
    }
}

/// Parsed HLO module text (the real crate re-parses instruction ids; the
/// stub just retains the text so errors can reference it).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::new(format!("{:?}: {e}", path.as_ref())))?;
        Ok(HloModuleProto { text })
    }

    pub fn from_text(text: &str) -> HloModuleProto {
        HloModuleProto { text: text.to_string() }
    }
}

/// An HLO computation ready for compilation.
pub struct XlaComputation {
    #[allow(dead_code)]
    hlo_text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { hlo_text: proto.text.clone() }
    }
}

const BACKEND_UNAVAILABLE: &str = "xla stub: no PJRT backend in this build — swap in the real \
     xla_extension bindings (see rust/vendor/xla/README.md) to compile/execute HLO";

/// PJRT client handle. The stub client boots (so smoke tests and
/// platform reporting work) but cannot compile programs.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu (xla stub, no PJRT backend)".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(BACKEND_UNAVAILABLE))
    }
}

/// A compiled executable. Unconstructible through the stub (compile
/// always errors), but the type and its API exist for the callers.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(BACKEND_UNAVAILABLE))
    }
}

/// A device buffer holding one execution output.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_boots_with_platform_name() {
        let c = PjRtClient::cpu().unwrap();
        assert!(!c.platform_name().is_empty());
    }

    #[test]
    fn literal_vec_roundtrip_per_type() {
        let f = Literal::vec1(&[1.0f32, -2.0, 3.5]);
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![1.0, -2.0, 3.5]);
        assert!(f.to_vec::<i32>().is_err());
        let i = Literal::vec1(&[3i32, -4]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![3, -4]);
        let s = Literal::scalar(7u32);
        assert_eq!(s.to_vec::<u32>().unwrap(), vec![7]);
        assert_eq!(s.dims(), &[] as &[i64]);
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[0i32; 6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.element_count(), 6);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn tuple_destructuring() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32)]);
        let inner = t.clone().to_tuple1().unwrap();
        assert_eq!(inner.to_vec::<f32>().unwrap(), vec![1.0]);
        let two = Literal::tuple(vec![Literal::scalar(1i32), Literal::scalar(2i32)]);
        assert!(two.clone().to_tuple1().is_err());
        assert_eq!(two.to_tuple().unwrap().len(), 2);
        assert!(Literal::scalar(0.0f32).to_tuple().is_err());
    }

    #[test]
    fn compile_reports_backend_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto::from_text("HloModule m"));
        let err = c.compile(&comp).unwrap_err();
        assert!(format!("{err:?}").contains("xla stub"));
    }
}
