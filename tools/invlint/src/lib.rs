//! In-tree invariant linter for the `dsm` crate (`cargo run -p invlint`).
//!
//! Zero dependencies and a hand-rolled lexer (the vendored-crates policy
//! rules out `syn`). The rules are *repo-specific* invariants that a green
//! build must make unrepresentable; each is individually testable against
//! the fixtures under `tools/invlint/tests/fixtures/`:
//!
//! * **W1 — wire-contract exhaustiveness.** In `dist/wire.rs`, a `match`
//!   whose arm patterns name `WirePayload::` / `WireFormat::` variants may
//!   not carry a `_ =>` (or catch-all binding) arm: every contract
//!   function names every variant, so a new wire format fails the lint —
//!   and the build — until every site handles it.
//! * **W2 — checkpoint key parity.** Every `ck.add("key", ..)` on the
//!   save path must have a matching `ck.get(..)` / `ck.with_prefix(..)`
//!   on the load path, and vice versa. `format!` keys match by wildcard
//!   (`"worker{w}.rng"` pairs with `"worker{}.rng"`). Checkpoint handles
//!   are named `ck` by convention so the lint can see them; keys must be
//!   string literals or `format!` of one.
//! * **W3 — cache-key discipline.** Every declared field of
//!   `OuterConfig` / `FaultPlan` must be named inside the type's
//!   `describe()` body: a knob that does not reach the experiment cache
//!   key silently reuses stale results.
//! * **W4 — billing discipline.** Outside `comm/mod.rs`, no numeric
//!   literal or arithmetic may appear at the top level of a
//!   `charge_*(..)` argument list: byte counts reach `SimClock` through
//!   `wire_bytes()` (or a binding of it), never an inline formula that
//!   can drift from the data path. Indexing (`payloads[0]`) is exempt.
//! * **W5 — RNG-stream hygiene.** `comm/faults.rs` (fault *policy* —
//!   pure data) and supervisor functions may not reference RNG
//!   identifiers, and `charge_*` arguments may not draw from `self.rng`
//!   (the trainer stream): fault timing rides the dedicated `fault_rng`.
//! * **W6 — no `.unwrap()` / `.expect(..)`** outside `#[cfg(test)]`.
//! * **W7 — documented `unsafe`.** Every `unsafe` token needs a
//!   `// SAFETY:` comment within the six preceding lines.
//! * **W8 — hot-path codec discipline.** Inside `train/` and `outer/`,
//!   the allocating codec conveniences (`pack_signs`, `unpack_signs`,
//!   `quantize_diff_into`) may not be called outside `#[cfg(test)]`:
//!   the round hot path reuses payload buffers through the exact-lane
//!   variants (`pack_signs_into`, `quantize_diff_slice`, the
//!   `PackedVotes`/`dist::kernels` decode paths), so a per-round
//!   allocation cannot creep back in behind a convenience call.
//!
//! A finding can be waived with a comment `invlint: allow(W6)` on the
//! same or the preceding line; the live tree currently needs no waivers.

use std::ops::Range;
use std::path::Path;

/// Token classes the rules care about. Lifetimes are dropped at lex time;
/// char literals lex as empty `Str` tokens so their quotes cannot confuse
/// string detection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

/// A lexed source file: tokens, a parallel "is inside `#[cfg(test)]`"
/// mask, and the comment list (for `SAFETY:` and waiver lookups).
pub struct SourceFile {
    rel: String,
    toks: Vec<Tok>,
    in_test: Vec<bool>,
    comments: Vec<(usize, String)>,
}

impl SourceFile {
    pub fn parse(rel: &str, text: &str) -> SourceFile {
        let (toks, comments) = lex(text);
        let in_test = test_mask(&toks);
        SourceFile { rel: rel.to_string(), toks, in_test, comments }
    }

    fn waived(&self, rule: &str, line: usize) -> bool {
        let tag = format!("invlint: allow({rule})");
        self.comments.iter().any(|(l, c)| (*l == line || *l + 1 == line) && c.contains(&tag))
    }
}

// ---------------------------------------------------------------- lexer

fn lex(text: &str) -> (Vec<Tok>, Vec<(usize, String)>) {
    let b: Vec<char> = text.chars().collect();
    let n = b.len();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            comments.push((line, b[start..i].iter().collect()));
            continue;
        }
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let (start, start_line) = (i, line);
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            comments.push((start_line, b[start..i.min(n)].iter().collect()));
            continue;
        }
        if c == '"' || c == 'r' || c == 'b' {
            if let Some((content, hashes, raw)) = string_open(&b, i) {
                let mut j = content;
                while j < n {
                    let ch = b[j];
                    if ch == '\n' {
                        line += 1;
                        j += 1;
                    } else if !raw && ch == '\\' {
                        j += 2;
                    } else if ch == '"' {
                        if raw {
                            let closed = (1..=hashes).all(|k| b.get(j + k) == Some(&'#'));
                            if closed {
                                break;
                            }
                            j += 1;
                        } else {
                            break;
                        }
                    } else {
                        j += 1;
                    }
                }
                let content_text: String = b[content..j.min(n)].iter().collect();
                toks.push(Tok { kind: Kind::Str, text: content_text, line });
                i = (j + 1 + hashes).min(n);
                continue;
            }
        }
        if c == '\'' {
            // Lifetime: 'ident not closed by a quote right after.
            if i + 1 < n && (b[i + 1].is_alphabetic() || b[i + 1] == '_') {
                let mut k = i + 2;
                while k < n && (b[k].is_alphanumeric() || b[k] == '_') {
                    k += 1;
                }
                if b.get(k) != Some(&'\'') {
                    i = k;
                    continue;
                }
            }
            // Char literal (possibly escaped).
            let mut k = i + 1;
            if b.get(k) == Some(&'\\') {
                k += 2;
            } else {
                k += 1;
            }
            while k < n && b[k] != '\'' {
                k += 1;
            }
            toks.push(Tok { kind: Kind::Str, text: String::new(), line });
            i = (k + 1).min(n);
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            toks.push(Tok { kind: Kind::Ident, text: b[start..i].iter().collect(), line });
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            while i < n {
                let d = b[i];
                if d.is_alphanumeric() || d == '_' {
                    i += 1;
                } else if d == '.' && b.get(i + 1).is_some_and(|x| x.is_ascii_digit()) {
                    i += 1;
                } else if (d == '+' || d == '-')
                    && matches!(b.get(i.wrapping_sub(1)), Some('e' | 'E'))
                    && b.get(i + 1).is_some_and(|x| x.is_ascii_digit())
                {
                    i += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok { kind: Kind::Num, text: b[start..i].iter().collect(), line });
            continue;
        }
        const TWO: [&str; 16] = [
            "::", "=>", "->", "..", "<<", ">>", "&&", "||", "==", "!=", "<=", ">=", "+=", "-=",
            "*=", "/=",
        ];
        let pair: String = b[i..n.min(i + 2)].iter().collect();
        if TWO.contains(&pair.as_str()) {
            toks.push(Tok { kind: Kind::Punct, text: pair, line });
            i += 2;
            continue;
        }
        toks.push(Tok { kind: Kind::Punct, text: c.to_string(), line });
        i += 1;
    }
    (toks, comments)
}

/// If position `i` opens a (possibly raw / byte) string literal, return
/// `(content_start, n_hashes, is_raw)`.
fn string_open(b: &[char], i: usize) -> Option<(usize, usize, bool)> {
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) == Some(&'r') {
        let mut k = j + 1;
        let mut hashes = 0usize;
        while b.get(k) == Some(&'#') {
            hashes += 1;
            k += 1;
        }
        if b.get(k) == Some(&'"') {
            return Some((k + 1, hashes, true));
        }
        return None; // an identifier starting with r / br
    }
    if b.get(j) == Some(&'"') {
        return Some((j + 1, 0, false));
    }
    None
}

fn is_punct(t: &Tok, s: &str) -> bool {
    t.kind == Kind::Punct && t.text == s
}

fn is_ident(t: &Tok, s: &str) -> bool {
    t.kind == Kind::Ident && t.text == s
}

/// Index of the delimiter matching `toks[open]` (counting only the
/// `o`/`c` pair — comments and strings are already out of the stream).
fn match_delim(toks: &[Tok], open: usize, o: &str, c: &str) -> usize {
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        if is_punct(&toks[i], o) {
            depth += 1;
        } else if is_punct(&toks[i], c) {
            depth -= 1;
            if depth == 0 {
                return i;
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Mark every token covered by a `#[cfg(test)]` item (the attribute, any
/// stacked attributes after it, and the item body through its closing
/// brace or semicolon).
fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if !(is_punct(&toks[i], "#") && i + 1 < toks.len() && is_punct(&toks[i + 1], "[")) {
            i += 1;
            continue;
        }
        let close = match_delim(toks, i + 1, "[", "]");
        let inner = &toks[i + 2..close.min(toks.len())];
        let is_test = inner.first().is_some_and(|t| is_ident(t, "cfg"))
            && inner.iter().any(|t| is_ident(t, "test"))
            && !inner.iter().any(|t| is_ident(t, "not"));
        if !is_test {
            i = close + 1;
            continue;
        }
        // Skip any further stacked attributes, then span the item.
        let mut j = close + 1;
        while j + 1 < toks.len() && is_punct(&toks[j], "#") && is_punct(&toks[j + 1], "[") {
            j = match_delim(toks, j + 1, "[", "]") + 1;
        }
        let mut depth = 0i64;
        let mut k = j;
        let end = loop {
            if k >= toks.len() {
                break toks.len();
            }
            if toks[k].kind == Kind::Punct {
                match toks[k].text.as_str() {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => break match_delim(toks, k, "{", "}") + 1,
                    ";" if depth == 0 => break k + 1,
                    _ => {}
                }
            }
            k += 1;
        };
        for m in mask.iter_mut().take(end.min(toks.len())).skip(i) {
            *m = true;
        }
        i = end;
    }
    mask
}

// ---------------------------------------------------------------- rules

fn push(out: &mut Vec<Violation>, f: &SourceFile, rule: &'static str, line: usize, msg: String) {
    if f.waived(rule, line) {
        return;
    }
    out.push(Violation { rule, file: f.rel.clone(), line, msg });
}

/// Scrutinee ends at the first `{` at depth 0; `match` in expression
/// position never puts a bare `{` in the scrutinee.
fn find_match_body(toks: &[Tok], m: usize) -> Option<usize> {
    let mut depth = 0i64;
    let mut k = m + 1;
    while k < toks.len() {
        if toks[k].kind == Kind::Punct {
            match toks[k].text.as_str() {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "{" if depth == 0 => return Some(k),
                ";" if depth == 0 => return None,
                _ => {}
            }
        }
        k += 1;
    }
    None
}

/// Pattern token ranges of every arm in the match body opening at `open`.
fn match_arm_patterns(toks: &[Tok], open: usize) -> Vec<Range<usize>> {
    let close = match_delim(toks, open, "{", "}");
    let mut arms = Vec::new();
    let mut i = open + 1;
    while i < close {
        let pat_start = i;
        let mut depth = 0i64;
        let mut guard = None;
        while i < close {
            let t = &toks[i];
            if t.kind == Kind::Punct {
                match t.text.as_str() {
                    "(" | "[" | "{" => depth += 1,
                    ")" | "]" | "}" => depth -= 1,
                    "=>" if depth == 0 => break,
                    _ => {}
                }
            }
            if depth == 0 && is_ident(t, "if") && guard.is_none() {
                guard = Some(i);
            }
            i += 1;
        }
        if i >= close {
            break;
        }
        arms.push(pat_start..guard.unwrap_or(i));
        i += 1; // past `=>`
        if i < close && is_punct(&toks[i], "{") {
            i = match_delim(toks, i, "{", "}") + 1;
            if i < close && is_punct(&toks[i], ",") {
                i += 1;
            }
        } else {
            let mut d = 0i64;
            while i < close {
                let t = &toks[i];
                if t.kind == Kind::Punct {
                    match t.text.as_str() {
                        "(" | "[" | "{" => d += 1,
                        ")" | "]" | "}" => d -= 1,
                        "," if d == 0 => {
                            i += 1;
                            break;
                        }
                        _ => {}
                    }
                }
                i += 1;
            }
        }
    }
    arms
}

fn pattern_is_catch_all(pat: &[Tok]) -> bool {
    let toks: Vec<&Tok> = pat
        .iter()
        .filter(|t| !(is_ident(t, "ref") || is_ident(t, "mut")))
        .collect();
    if toks.len() != 1 {
        return false;
    }
    let t = toks[0];
    is_punct(t, "_")
        || (t.kind == Kind::Ident
            && t.text.chars().next().is_some_and(|c| c.is_lowercase() || c == '_'))
}

/// W1: in `dist/wire.rs`, matches over the wire contract enums must name
/// every variant — no `_ =>` and no catch-all binding arm.
fn w1_wire_exhaustiveness(f: &SourceFile, out: &mut Vec<Violation>) {
    if f.rel != "dist/wire.rs" {
        return;
    }
    for (mi, t) in f.toks.iter().enumerate() {
        if f.in_test[mi] || !is_ident(t, "match") {
            continue;
        }
        let Some(open) = find_match_body(&f.toks, mi) else {
            continue;
        };
        let arms = match_arm_patterns(&f.toks, open);
        let on_contract = arms.iter().any(|a| {
            f.toks[a.clone()].windows(2).any(|w| {
                (is_ident(&w[0], "WirePayload") || is_ident(&w[0], "WireFormat"))
                    && is_punct(&w[1], "::")
            })
        });
        if !on_contract {
            continue;
        }
        for a in &arms {
            let pat = &f.toks[a.clone()];
            if !pat.is_empty() && pattern_is_catch_all(pat) {
                push(
                    out,
                    f,
                    "W1",
                    pat[0].line,
                    format!(
                        "catch-all arm `{}` in a WirePayload/WireFormat match: name every \
                         variant so a new wire format fails the build at every contract site",
                        pat[0].text
                    ),
                );
            }
        }
    }
}

// W2: checkpoint key parity. Keys are collected across the whole file set
// and reconciled at the end.

#[derive(Default)]
pub struct CkIndex {
    saves: Vec<CkKey>,
    gets: Vec<CkKey>,
    prefixes: Vec<CkKey>,
}

struct CkKey {
    pattern: String,
    file: String,
    line: usize,
    waived: bool,
}

fn w2_collect(f: &SourceFile, idx: &mut CkIndex, out: &mut Vec<Violation>) {
    let toks = &f.toks;
    let mut i = 0usize;
    while i + 3 < toks.len() {
        if f.in_test[i]
            || !is_ident(&toks[i], "ck")
            || !is_punct(&toks[i + 1], ".")
            || !is_punct(&toks[i + 3], "(")
        {
            i += 1;
            continue;
        }
        let method = toks[i + 2].text.clone();
        if toks[i + 2].kind != Kind::Ident
            || (method != "add" && method != "get" && method != "with_prefix")
        {
            i += 1;
            continue;
        }
        let line = toks[i].line;
        match first_arg_key(toks, i + 4) {
            Some(raw) => {
                let key = CkKey {
                    pattern: normalize_key(&raw),
                    file: f.rel.clone(),
                    line,
                    waived: f.waived("W2", line),
                };
                match method.as_str() {
                    "add" => idx.saves.push(key),
                    "get" => idx.gets.push(key),
                    _ => idx.prefixes.push(key),
                }
            }
            None => push(
                out,
                f,
                "W2",
                line,
                format!(
                    "checkpoint `{method}` key is not a string literal or `format!` of one — \
                     key parity cannot be checked mechanically"
                ),
            ),
        }
        i += 4;
    }
}

/// First argument of a checkpoint call, if it is a string literal or a
/// `format!` with a literal template (optionally behind `&`).
fn first_arg_key(toks: &[Tok], mut j: usize) -> Option<String> {
    if j < toks.len() && is_punct(&toks[j], "&") {
        j += 1;
    }
    if j < toks.len() && toks[j].kind == Kind::Str {
        return Some(toks[j].text.clone());
    }
    if j + 3 < toks.len()
        && is_ident(&toks[j], "format")
        && is_punct(&toks[j + 1], "!")
        && is_punct(&toks[j + 2], "(")
        && toks[j + 3].kind == Kind::Str
    {
        return Some(toks[j + 3].text.clone());
    }
    None
}

/// `format!` template -> wildcard pattern: `{..}` becomes `*`, `{{`/`}}`
/// become literal braces.
fn normalize_key(raw: &str) -> String {
    let chars: Vec<char> = raw.chars().collect();
    let mut out = String::new();
    let mut i = 0usize;
    while i < chars.len() {
        match chars[i] {
            '{' if chars.get(i + 1) == Some(&'{') => {
                out.push('{');
                i += 2;
            }
            '}' if chars.get(i + 1) == Some(&'}') => {
                out.push('}');
                i += 2;
            }
            '{' => {
                while i < chars.len() && chars[i] != '}' {
                    i += 1;
                }
                i += 1;
                out.push('*');
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    out
}

/// Can two `*`-wildcard patterns match a common concrete string? A `*`
/// matches a (possibly empty) run of non-`.` characters: every live
/// interpolation is an integer id, and letting a star swallow a `.`
/// would make `worker*.opt*` shadow `worker*.rng` — deleting the rng
/// save line must fail the lint, not hide behind a sibling key family.
fn patterns_overlap(a: &str, b: &str) -> bool {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let (la, lb) = (a.len(), b.len());
    let mut dp = vec![vec![false; lb + 1]; la + 1];
    dp[la][lb] = true;
    for i in (0..=la).rev() {
        for j in (0..=lb).rev() {
            if i == la && j == lb {
                continue;
            }
            let mut v = false;
            if i < la && a[i] == '*' {
                v = v || dp[i + 1][j] || (j < lb && b[j] != '.' && dp[i][j + 1]);
            }
            if j < lb && b[j] == '*' {
                v = v || dp[i][j + 1] || (i < la && a[i] != '.' && dp[i + 1][j]);
            }
            if i < la && j < lb && a[i] != '*' && b[j] != '*' && a[i] == b[j] {
                v = v || dp[i + 1][j + 1];
            }
            dp[i][j] = v;
        }
    }
    dp[0][0]
}

fn w2_reconcile(idx: &CkIndex, out: &mut Vec<Violation>) {
    let prefix_overlap = |save: &str, prefix: &str| patterns_overlap(save, &format!("{prefix}*"));
    for s in &idx.saves {
        let read = idx.gets.iter().any(|g| patterns_overlap(&s.pattern, &g.pattern))
            || idx.prefixes.iter().any(|p| prefix_overlap(&s.pattern, &p.pattern));
        if !read && !s.waived {
            out.push(Violation {
                rule: "W2",
                file: s.file.clone(),
                line: s.line,
                msg: format!(
                    "checkpoint key `{}` is written on the save path but never read back \
                     (the PR-4 resume-divergence bug class)",
                    s.pattern
                ),
            });
        }
    }
    for g in &idx.gets {
        let written = idx.saves.iter().any(|s| patterns_overlap(&s.pattern, &g.pattern));
        if !written && !g.waived {
            out.push(Violation {
                rule: "W2",
                file: g.file.clone(),
                line: g.line,
                msg: format!(
                    "checkpoint key `{}` is read but never written on the save path",
                    g.pattern
                ),
            });
        }
    }
    for p in &idx.prefixes {
        let written = idx.saves.iter().any(|s| prefix_overlap(&s.pattern, &p.pattern));
        if !written && !p.waived {
            out.push(Violation {
                rule: "W2",
                file: p.file.clone(),
                line: p.line,
                msg: format!("checkpoint prefix `{}` matches no key on the save path", p.pattern),
            });
        }
    }
}

// W3: cache-key discipline.

const W3_TYPES: [&str; 2] = ["OuterConfig", "FaultPlan"];

fn w3_cache_key(f: &SourceFile, out: &mut Vec<Violation>) {
    for ty in W3_TYPES {
        let Some((decl_line, fields)) = declared_fields(f, ty) else {
            continue;
        };
        let Some(body) = describe_body(f, ty) else {
            push(
                out,
                f,
                "W3",
                decl_line,
                format!("`{ty}` is declared here but has no `describe()` in an `impl {ty}` block"),
            );
            continue;
        };
        for (field, fline) in &fields {
            let named = f.toks[body.clone()]
                .iter()
                .any(|t| t.kind == Kind::Ident && t.text == *field);
            if !named {
                push(
                    out,
                    f,
                    "W3",
                    *fline,
                    format!(
                        "`{ty}::{field}` never appears in `{ty}::describe()` — the experiment \
                         cache key would not split on it"
                    ),
                );
            }
        }
    }
}

/// Field identifiers declared in `struct ty { .. }` / `enum ty { .. }`
/// (for enums: the named fields of every struct-like variant).
fn declared_fields(f: &SourceFile, ty: &str) -> Option<(usize, Vec<(String, usize)>)> {
    let toks = &f.toks;
    let mut i = 0usize;
    while i + 1 < toks.len() {
        let kw = is_ident(&toks[i], "struct") || is_ident(&toks[i], "enum");
        if !kw || !is_ident(&toks[i + 1], ty) || f.in_test[i] {
            i += 1;
            continue;
        }
        let decl_line = toks[i].line;
        let mut j = i + 2;
        while j < toks.len() && !is_punct(&toks[j], "{") {
            if is_punct(&toks[j], ";") {
                return Some((decl_line, Vec::new()));
            }
            j += 1;
        }
        if j >= toks.len() {
            return None;
        }
        let close = match_delim(toks, j, "{", "}");
        let mut fields = Vec::new();
        let mut k = j + 1;
        while k + 1 < close {
            if toks[k].kind == Kind::Ident && is_punct(&toks[k + 1], ":") {
                fields.push((toks[k].text.clone(), toks[k].line));
            }
            k += 1;
        }
        return Some((decl_line, fields));
    }
    None
}

/// Token range of the `describe()` body inside any `impl ty { .. }`.
fn describe_body(f: &SourceFile, ty: &str) -> Option<Range<usize>> {
    let toks = &f.toks;
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if !is_ident(&toks[i], "impl") || !is_ident(&toks[i + 1], ty) || f.in_test[i] {
            i += 1;
            continue;
        }
        if !is_punct(&toks[i + 2], "{") {
            i += 1;
            continue;
        }
        let close = match_delim(toks, i + 2, "{", "}");
        let mut j = i + 3;
        while j + 1 < close {
            if is_ident(&toks[j], "fn") && is_ident(&toks[j + 1], "describe") {
                return fn_body_range(toks, j + 2);
            }
            j += 1;
        }
        i = close + 1;
    }
    None
}

/// Body range of a fn whose signature starts at `from` (just past the
/// name); `None` for a bodyless trait method.
fn fn_body_range(toks: &[Tok], from: usize) -> Option<Range<usize>> {
    let mut depth = 0i64;
    let mut k = from;
    while k < toks.len() {
        if toks[k].kind == Kind::Punct {
            match toks[k].text.as_str() {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                ";" if depth == 0 => return None,
                "{" if depth == 0 => return Some(k..match_delim(toks, k, "{", "}")),
                _ => {}
            }
        }
        k += 1;
    }
    None
}

/// Argument token ranges of every non-test `charge_*(..)` call.
fn charge_call_args(f: &SourceFile) -> Vec<(usize, Range<usize>)> {
    let toks = &f.toks;
    let mut calls = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        let hit = !f.in_test[i]
            && is_punct(&toks[i], ".")
            && toks[i + 1].kind == Kind::Ident
            && toks[i + 1].text.starts_with("charge_")
            && is_punct(&toks[i + 2], "(");
        if !hit {
            i += 1;
            continue;
        }
        let close = match_delim(toks, i + 2, "(", ")");
        calls.push((i + 1, i + 3..close));
        i = close;
    }
    calls
}

/// W4: byte counts must flow through `wire_bytes()` — no literals or
/// arithmetic at the top level of a `charge_*` argument list.
fn w4_billing(f: &SourceFile, out: &mut Vec<Violation>) {
    if f.rel == "comm/mod.rs" {
        return;
    }
    for (name_idx, args) in charge_call_args(f) {
        let name = f.toks[name_idx].text.clone();
        let mut bracket = 0i64;
        for t in &f.toks[args] {
            match t.kind {
                Kind::Punct => match t.text.as_str() {
                    "[" => bracket += 1,
                    "]" => bracket -= 1,
                    "+" | "-" | "*" | "/" | "%" | "<<" | ">>" if bracket == 0 => {
                        push(
                            out,
                            f,
                            "W4",
                            t.line,
                            format!(
                                "arithmetic `{}` in a `{name}` argument: byte counts reach the \
                                 clock through wire_bytes(), never an inline formula",
                                t.text
                            ),
                        );
                    }
                    _ => {}
                },
                Kind::Num if bracket == 0 => {
                    push(
                        out,
                        f,
                        "W4",
                        t.line,
                        format!(
                            "numeric literal `{}` in a `{name}` argument: byte counts reach the \
                             clock through wire_bytes()",
                            t.text
                        ),
                    );
                }
                _ => {}
            }
        }
    }
}

/// W5: RNG-stream hygiene.
fn w5_rng_hygiene(f: &SourceFile, out: &mut Vec<Violation>) {
    let toks = &f.toks;
    if f.rel == "comm/faults.rs" {
        for (i, t) in toks.iter().enumerate() {
            let is_rng = t.kind == Kind::Ident && t.text.to_ascii_lowercase().contains("rng");
            if !f.in_test[i] && is_rng {
                push(
                    out,
                    f,
                    "W5",
                    t.line,
                    format!(
                        "`{}` in comm/faults.rs: the fault plan is pure policy data — draws \
                         happen on the trainer's dedicated fault stream",
                        t.text
                    ),
                );
            }
        }
    }
    let mut i = 0usize;
    while i + 1 < toks.len() {
        let supervisor_fn = !f.in_test[i]
            && is_ident(&toks[i], "fn")
            && toks[i + 1].kind == Kind::Ident
            && (toks[i + 1].text.contains("supervisor") || toks[i + 1].text == "score_survivors");
        if supervisor_fn {
            if let Some(body) = fn_body_range(toks, i + 2) {
                for t in &toks[body] {
                    if t.kind == Kind::Ident && t.text.to_ascii_lowercase().contains("rng") {
                        push(
                            out,
                            f,
                            "W5",
                            t.line,
                            format!(
                                "`{}` inside `{}`: supervisor scoring must stay deterministic \
                                 (no trainer/worker/fault RNG)",
                                t.text, toks[i + 1].text
                            ),
                        );
                    }
                }
            }
        }
        i += 1;
    }
    for (name_idx, args) in charge_call_args(f) {
        let args_toks = &toks[args];
        for w in args_toks.windows(3) {
            if is_ident(&w[0], "self") && is_punct(&w[1], ".") && is_ident(&w[2], "rng") {
                push(
                    out,
                    f,
                    "W5",
                    w[0].line,
                    format!(
                        "`self.rng` in a `{}` argument: fault/straggler timing draws from the \
                         dedicated fault_rng stream, not the trainer stream",
                        toks[name_idx].text
                    ),
                );
            }
        }
    }
}

/// W6: no `.unwrap()` / `.expect(..)` outside `#[cfg(test)]`.
fn w6_unwrap(f: &SourceFile, out: &mut Vec<Violation>) {
    let toks = &f.toks;
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if !f.in_test[i] && is_punct(&toks[i], ".") && is_punct(&toks[i + 2], "(") {
            if is_ident(&toks[i + 1], "unwrap") && toks.get(i + 3).is_some_and(|t| is_punct(t, ")"))
            {
                push(
                    out,
                    f,
                    "W6",
                    toks[i + 1].line,
                    "`.unwrap()` outside #[cfg(test)]: match / let-else on the named invariant, \
                     or propagate the error"
                        .to_string(),
                );
            } else if is_ident(&toks[i + 1], "expect") {
                push(
                    out,
                    f,
                    "W6",
                    toks[i + 1].line,
                    "`.expect(..)` outside #[cfg(test)]: match / let-else on the named \
                     invariant, or propagate the error"
                        .to_string(),
                );
            }
        }
        i += 1;
    }
}

/// W7: every `unsafe` needs a `// SAFETY:` comment within six lines above.
fn w7_safety(f: &SourceFile, out: &mut Vec<Violation>) {
    for (i, t) in f.toks.iter().enumerate() {
        if f.in_test[i] || !is_ident(t, "unsafe") {
            continue;
        }
        let near = f
            .comments
            .iter()
            .any(|(l, c)| *l <= t.line && t.line - *l <= 6 && c.contains("SAFETY:"));
        if !near {
            push(
                out,
                f,
                "W7",
                t.line,
                "`unsafe` without a `// SAFETY:` comment in the six preceding lines".to_string(),
            );
        }
    }
}

/// W8: no allocating codec entry points on the round hot path — inside
/// `train/` / `outer/`, calls to `pack_signs` / `unpack_signs` /
/// `quantize_diff_into` (ident directly followed by `(`) are flagged
/// outside `#[cfg(test)]`.
fn w8_codec_hot_path(f: &SourceFile, out: &mut Vec<Violation>) {
    if !(f.rel.starts_with("train/") || f.rel.starts_with("outer/")) {
        return;
    }
    const BANNED: [&str; 3] = ["pack_signs", "unpack_signs", "quantize_diff_into"];
    for (i, t) in f.toks.iter().enumerate() {
        if f.in_test[i] || t.kind != Kind::Ident || !BANNED.contains(&t.text.as_str()) {
            continue;
        }
        if !f.toks.get(i + 1).is_some_and(|n| is_punct(n, "(")) {
            continue;
        }
        push(
            out,
            f,
            "W8",
            t.line,
            format!(
                "allocating codec entry point `{}(..)` on the round hot path: use the \
                 preallocated `_into`/`_slice` variant over the payload's own buffers",
                t.text
            ),
        );
    }
}

// ---------------------------------------------------------------- driver

/// Lint a set of `(relative_path, source_text)` pairs. Paths use `/`
/// separators relative to `rust/src` (path-scoped rules key on them).
pub fn lint_sources(files: &[(String, String)]) -> Vec<Violation> {
    let parsed: Vec<SourceFile> = files.iter().map(|(r, t)| SourceFile::parse(r, t)).collect();
    let mut out = Vec::new();
    let mut ck = CkIndex::default();
    for f in &parsed {
        w1_wire_exhaustiveness(f, &mut out);
        w2_collect(f, &mut ck, &mut out);
        w3_cache_key(f, &mut out);
        w4_billing(f, &mut out);
        w5_rng_hygiene(f, &mut out);
        w6_unwrap(f, &mut out);
        w7_safety(f, &mut out);
        w8_codec_hot_path(f, &mut out);
    }
    w2_reconcile(&ck, &mut out);
    out.sort_by(|a, b| (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule)));
    out
}

/// Walk a source root (normally `rust/src`) and lint every `.rs` file.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    collect_rs(root, root, &mut files)?;
    files.sort();
    Ok(lint_sources(&files))
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<(String, String)>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let stripped = path.strip_prefix(root).unwrap_or(&path);
            let rel = stripped.to_string_lossy().replace('\\', "/");
            out.push((rel, std::fs::read_to_string(&path)?));
        }
    }
    Ok(())
}

/// One line per violation, `file:line [rule] message`.
pub fn render(violations: &[Violation]) -> String {
    let mut s = String::new();
    for v in violations {
        s.push_str(&format!("{}:{} [{}] {}\n", v.file, v.line, v.rule, v.msg));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexer_handles_strings_comments_chars_and_lifetimes() {
        let src = r##"
            // line "comment"
            /* block /* nested */ still comment */
            fn f<'a>(x: &'a str) -> char {
                let s = "quoted \" brace {";
                let r = r#"raw " text"#;
                let b = b"bytes";
                let c = '{';
                let d = '\'';
                's'
            }
        "##;
        let (toks, comments) = lex(src);
        assert_eq!(comments.len(), 2);
        let strs: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == Kind::Str && !t.text.is_empty())
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(strs, [r#"quoted \" brace {"#, r#"raw " text"#, "bytes"]);
        // The brace inside the char literal must not unbalance anything.
        let opens = toks.iter().filter(|t| is_punct(t, "{")).count();
        let closes = toks.iter().filter(|t| is_punct(t, "}")).count();
        assert_eq!(opens, closes);
    }

    #[test]
    fn test_mask_covers_cfg_test_modules_only() {
        let src = r#"
            fn live() { x.unwrap(); }
            #[cfg(test)]
            mod tests {
                #[test]
                fn t() { y.unwrap(); }
            }
            fn also_live() { z.unwrap(); }
        "#;
        let f = SourceFile::parse("m.rs", src);
        let mut out = Vec::new();
        w6_unwrap(&f, &mut out);
        assert_eq!(out.len(), 2, "{out:?}");
        assert_eq!(out[0].line, 2);
        assert_eq!(out[1].line, 8);
    }

    #[test]
    fn waiver_comment_suppresses_a_finding() {
        let src = "fn f() {\n    x.unwrap(); // invlint: allow(W6) lexer-verified\n}\n";
        let f = SourceFile::parse("m.rs", src);
        let mut out = Vec::new();
        w6_unwrap(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn key_patterns_overlap_like_format_keys() {
        assert!(patterns_overlap("worker*.rng", "worker*.rng"));
        assert!(patterns_overlap(&normalize_key("worker{w}.rng"), "worker*.rng"));
        assert!(patterns_overlap("global", "global"));
        assert!(!patterns_overlap("meta.local_step", "meta.local_step64"));
        assert!(!patterns_overlap("trainer.rng", "trainer.fault_rng"));
        // with_prefix("outer.") reads keys saved as outer.{i}
        assert!(patterns_overlap("outer.*", &format!("{}{}", normalize_key("outer."), "*")));
        assert_eq!(normalize_key("w{{x}}y{i}"), "w{x}y*");
        // a star never swallows a `.`: sibling key families stay disjoint,
        // so deleting one family's save line cannot hide behind another's
        assert!(!patterns_overlap("worker*.rng", "worker*.opt*"));
        assert!(!patterns_overlap("worker*.topk_residual", "worker*.opt*"));
    }

    #[test]
    fn w5_flags_trainer_stream_in_charge_args_but_not_fault_rng() {
        let src = "fn round(&mut self) {\n    self.clock.charge_exchange(&self.cfg.comm, n, \
                   &p, &mut self.rng);\n}\n";
        let f = SourceFile::parse("train/trainer.rs", src);
        let mut out = Vec::new();
        w5_rng_hygiene(&f, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        let ok = "fn round(&mut self) {\n    self.clock.charge_exchange(&self.cfg.comm, n, \
                  &p, &mut self.fault_rng);\n}\n";
        let f = SourceFile::parse("train/trainer.rs", ok);
        let mut out = Vec::new();
        w5_rng_hygiene(&f, &mut out);
        assert!(out.is_empty(), "{out:?}");
    }
}
