use std::path::PathBuf;
use std::process::ExitCode;

/// `cargo run -p invlint [src-root]` — lints `rust/src` by default and
/// exits non-zero on any violation (the same pass tier-1 runs from
/// `rust/tests/invariants.rs`).
fn main() -> ExitCode {
    let root = match std::env::args().nth(1) {
        Some(p) => PathBuf::from(p),
        None => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../rust/src"),
    };
    match invlint::lint_tree(&root) {
        Ok(v) if v.is_empty() => {
            println!("invlint: {} is clean (rules W1-W8)", root.display());
            ExitCode::SUCCESS
        }
        Ok(v) => {
            eprint!("{}", invlint::render(&v));
            eprintln!("invlint: {} violation(s)", v.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("invlint: cannot walk {}: {e}", root.display());
            ExitCode::FAILURE
        }
    }
}
