// W1 failing fixture: catch-all arms in WirePayload/WireFormat matches.
impl WirePayload {
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            WirePayload::DenseF32(v) => Some(v),
            _ => None,
        }
    }

    pub fn layout(&self) -> Option<&TopKLayout> {
        match self {
            WirePayload::TopK { layout, .. } => Some(layout),
            other => None,
        }
    }
}
