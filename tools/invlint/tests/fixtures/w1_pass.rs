// W1 clean fixture: every WirePayload match names every variant; matches
// over non-contract types may still use wildcards, and string-keyed
// parse() matches (open input set) are out of scope for the rule.
impl WirePayload {
    pub fn as_dense(&self) -> Option<&[f32]> {
        match self {
            WirePayload::DenseF32(v) => Some(v),
            WirePayload::PackedSigns(_)
            | WirePayload::QuantizedI8 { .. }
            | WirePayload::QuantizedI8PerTensor { .. }
            | WirePayload::TopK { .. } => None,
        }
    }
}

impl WireFormat {
    pub fn parse(s: &str) -> Option<WireFormat> {
        match s {
            "dense" => Some(WireFormat::DenseF32),
            _ => None,
        }
    }
}

fn unrelated(x: Option<u32>) -> u32 {
    match x {
        Some(v) => v,
        _ => 0,
    }
}
