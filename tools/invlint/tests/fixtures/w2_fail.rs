// W2 failing fixture: one orphan save key and one orphan read key.
impl Trainer {
    fn save_into(&self, ck: &mut Checkpoint) {
        ck.add("trainer.clock", &self.clock_words());
        ck.add("trainer.orphan", &self.orphan_words());
    }

    fn load_from(&mut self, ck: &Checkpoint) -> Result<()> {
        self.load_clock(ck.get("trainer.clock")?);
        self.load_ghost(ck.get("trainer.ghost")?);
        Ok(())
    }
}
