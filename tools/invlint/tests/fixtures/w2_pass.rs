// W2 clean fixture: every save key has a load-path read (directly, via a
// format! wildcard, or via with_prefix) and vice versa.
impl Trainer {
    fn save_into(&self, ck: &mut Checkpoint) {
        ck.add("global", &self.global);
        ck.add(&format!("outer.{i}", i = 0), &self.outer_words());
        for w in &self.workers {
            ck.add(&format!("worker{}.rng", w.id), &w.rng_words());
        }
    }

    fn load_from(&mut self, ck: &Checkpoint) -> Result<()> {
        self.global = ck.get("global")?.to_vec();
        self.load_outer(ck.with_prefix("outer."));
        for w in &mut self.workers {
            w.load_rng(ck.get(&format!("worker{}.rng", w.id))?);
        }
        Ok(())
    }
}
