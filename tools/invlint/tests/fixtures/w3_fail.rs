// W3 failing fixture: a declared knob (drop_prob) that describe() never
// names — the experiment cache key would not split on it.
pub struct FaultPlan {
    pub churn_prob: f64,
    pub drop_prob: f64,
}

impl FaultPlan {
    pub fn describe(&self) -> String {
        format!("faults[churn={}]", self.churn_prob)
    }
}
