// W3 clean fixture: every declared field of both audited types is named
// in its describe() body.
pub struct FaultPlan {
    pub churn_prob: f64,
    pub drop_prob: f64,
}

impl FaultPlan {
    pub fn describe(&self) -> String {
        format!("faults[churn={},drop={}]", self.churn_prob, self.drop_prob)
    }
}

pub enum OuterConfig {
    SignMomentum { eta: f32, beta: f32 },
    LocalAvg,
}

impl OuterConfig {
    pub fn describe(&self) -> String {
        match *self {
            OuterConfig::SignMomentum { eta, beta } => format!("signm[eta={eta},beta={beta}]"),
            OuterConfig::LocalAvg => "localavg".to_string(),
        }
    }
}
