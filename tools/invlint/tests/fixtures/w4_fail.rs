// W4 failing fixture: an inline byte formula in a charge_* argument.
impl Trainer {
    fn bill_round(&mut self, n: usize, p: usize) {
        self.clock
            .charge_allreduce(&self.cfg.comm, n, p / 8 + 8, &mut self.fault_rng);
        self.clock.charge_exchange(&self.cfg.comm, 2, &self.payload, &mut self.fault_rng);
    }
}
