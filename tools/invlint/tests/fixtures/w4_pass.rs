// W4 clean fixture: byte counts reach the clock through wire_bytes()
// (or a binding of it); indexing inside an argument is exempt.
impl Trainer {
    fn bill_round(&mut self, n: usize) {
        let bytes = self.payloads[0].wire_bytes();
        self.clock.charge_allreduce(&self.cfg.comm, n, bytes, &mut self.fault_rng);
        self.clock
            .charge_exchange_among(&self.cfg.comm, n, arrived, &self.payloads[0], &mut self.fault_rng);
    }
}
