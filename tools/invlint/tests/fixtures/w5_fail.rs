// W5 failing fixture (lints as comm/faults.rs): the fault plan drawing
// its own randomness instead of staying pure policy data.
impl FaultPlan {
    pub fn worker_dropped(&self, rng: &mut Rng) -> bool {
        rng.next_f64() < self.drop_prob
    }
}
