// W5 clean fixture (lints as comm/faults.rs): pure policy data — the
// trainer draws from its dedicated fault stream and hands outcomes in.
impl FaultPlan {
    pub fn describe(&self) -> String {
        format!("faults[drop={}]", self.drop_prob)
    }

    pub fn any_enabled(&self) -> bool {
        self.drop_prob > 0.0
    }
}
