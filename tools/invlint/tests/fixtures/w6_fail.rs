// W6 failing fixture: unwrap/expect on the live (non-test) path.
pub fn load(path: &Path) -> Config {
    let text = std::fs::read_to_string(path).unwrap();
    parse(&text).expect("config parses")
}
