// W6 clean fixture: the live path propagates errors; tests may unwrap.
pub fn load(path: &Path) -> Result<Config> {
    let text = std::fs::read_to_string(path)?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_the_sample() {
        load(Path::new("sample.toml")).unwrap();
    }
}
