// W7 failing fixture: an undocumented unsafe block.
pub fn as_bytes(buf: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(buf.as_ptr().cast::<u8>(), buf.len() * 4) }
}
