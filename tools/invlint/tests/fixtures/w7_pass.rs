// W7 clean fixture: every unsafe carries a SAFETY comment close above.
pub fn as_bytes(buf: &[f32]) -> &[u8] {
    // SAFETY: any f32 bit pattern is a valid [u8; 4]; the pointer and
    // length come from the same live slice, and u8 has no alignment
    // requirement.
    unsafe { std::slice::from_raw_parts(buf.as_ptr().cast::<u8>(), buf.len() * 4) }
}
