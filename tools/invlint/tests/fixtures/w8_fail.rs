// W8 true-positive fixture: a round hot path leaning on the allocating
// codec conveniences (one violation per banned entry point).

use crate::dist::codec;

fn exchange_round(diff: &[f32], start: &[f32], end: &[f32]) -> (Vec<u8>, Vec<f32>, Vec<u8>) {
    let packed = codec::pack_signs(diff);
    let decoded = codec::unpack_signs(&packed, diff.len());
    let mut q = Vec::new();
    let _scale = codec::quantize_diff_into(start, end, &mut q);
    (packed, decoded, q)
}
