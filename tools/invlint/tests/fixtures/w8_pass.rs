// W8 clean fixture: the hot path reuses preallocated buffers through
// the exact-lane variants; the allocating conveniences only appear
// inside #[cfg(test)], where they are exempt.

use crate::dist::codec;

fn exchange_round(diff: &[f32], start: &[f32], end: &[f32], bytes: &mut [u8]) -> f32 {
    let mut packed = Vec::new();
    codec::pack_signs_into(diff, &mut packed);
    codec::quantize_diff_slice(start, end, bytes)
}

#[cfg(test)]
mod tests {
    #[test]
    fn round_trip() {
        let packed = crate::dist::codec::pack_signs(&[1.0, -1.0]);
        let signs = crate::dist::codec::unpack_signs(&packed, 2);
        assert_eq!(signs.len(), 2);
    }
}
