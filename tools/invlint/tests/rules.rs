//! Fixture tests: every rule has a true-positive fixture it must flag and
//! a clean fixture it must pass. Fixtures are data (never compiled), fed
//! through `lint_sources` under the relative path that triggers the
//! rule's scoping (`dist/wire.rs` for W1, `comm/faults.rs` for W5).

use invlint::{lint_sources, Violation};

fn lint_as(rel: &str, text: &str) -> Vec<Violation> {
    lint_sources(&[(rel.to_string(), text.to_string())])
}

fn hits<'a>(v: &'a [Violation], rule: &str) -> Vec<&'a Violation> {
    v.iter().filter(|x| x.rule == rule).collect()
}

#[test]
fn w1_flags_catch_all_arms_in_wire_contract_matches() {
    let bad = lint_as("dist/wire.rs", include_str!("fixtures/w1_fail.rs"));
    let w1 = hits(&bad, "W1");
    assert_eq!(w1.len(), 2, "{bad:?}"); // `_ =>` and a binding `other =>`
    assert!(w1[0].msg.contains("catch-all"), "{:?}", w1[0]);

    let good = lint_as("dist/wire.rs", include_str!("fixtures/w1_pass.rs"));
    assert!(hits(&good, "W1").is_empty(), "{good:?}");

    // The rule is scoped to dist/wire.rs: the same catch-all elsewhere
    // (e.g. a config-level match on WireFormat) is allowed.
    let elsewhere = lint_as("config/mod.rs", include_str!("fixtures/w1_fail.rs"));
    assert!(hits(&elsewhere, "W1").is_empty(), "{elsewhere:?}");
}

#[test]
fn w2_flags_orphan_saves_and_orphan_reads() {
    let bad = lint_as("train/trainer.rs", include_str!("fixtures/w2_fail.rs"));
    let w2 = hits(&bad, "W2");
    assert_eq!(w2.len(), 2, "{bad:?}");
    assert!(w2.iter().any(|v| v.msg.contains("trainer.orphan") && v.msg.contains("never read")));
    assert!(w2.iter().any(|v| v.msg.contains("trainer.ghost") && v.msg.contains("never written")));

    let good = lint_as("train/trainer.rs", include_str!("fixtures/w2_pass.rs"));
    assert!(hits(&good, "W2").is_empty(), "{good:?}");
}

#[test]
fn w3_flags_knobs_missing_from_describe() {
    let bad = lint_as("comm/faults.rs", include_str!("fixtures/w3_fail.rs"));
    let w3 = hits(&bad, "W3");
    assert_eq!(w3.len(), 1, "{bad:?}");
    assert!(w3[0].msg.contains("drop_prob"), "{:?}", w3[0]);

    let good = lint_as("comm/faults.rs", include_str!("fixtures/w3_pass.rs"));
    assert!(hits(&good, "W3").is_empty(), "{good:?}");
}

#[test]
fn w4_flags_inline_byte_formulas_in_charge_calls() {
    let bad = lint_as("train/trainer.rs", include_str!("fixtures/w4_fail.rs"));
    assert!(!hits(&bad, "W4").is_empty(), "{bad:?}");

    let good = lint_as("train/trainer.rs", include_str!("fixtures/w4_pass.rs"));
    assert!(hits(&good, "W4").is_empty(), "{good:?}");

    // comm/mod.rs is the one place byte formulas are legal (it *defines*
    // the cost model).
    let model = lint_as("comm/mod.rs", include_str!("fixtures/w4_fail.rs"));
    assert!(hits(&model, "W4").is_empty(), "{model:?}");
}

#[test]
fn w5_flags_rng_references_in_fault_policy_code() {
    let bad = lint_as("comm/faults.rs", include_str!("fixtures/w5_fail.rs"));
    assert!(!hits(&bad, "W5").is_empty(), "{bad:?}");

    let good = lint_as("comm/faults.rs", include_str!("fixtures/w5_pass.rs"));
    assert!(hits(&good, "W5").is_empty(), "{good:?}");
}

#[test]
fn w6_flags_unwrap_and_expect_outside_tests() {
    let bad = lint_as("config/mod.rs", include_str!("fixtures/w6_fail.rs"));
    let w6 = hits(&bad, "W6");
    assert_eq!(w6.len(), 2, "{bad:?}");

    let good = lint_as("config/mod.rs", include_str!("fixtures/w6_pass.rs"));
    assert!(hits(&good, "W6").is_empty(), "{good:?}");
}

#[test]
fn w7_requires_safety_comments_on_unsafe() {
    let bad = lint_as("train/checkpoint.rs", include_str!("fixtures/w7_fail.rs"));
    assert_eq!(hits(&bad, "W7").len(), 1, "{bad:?}");

    let good = lint_as("train/checkpoint.rs", include_str!("fixtures/w7_pass.rs"));
    assert!(hits(&good, "W7").is_empty(), "{good:?}");
}

#[test]
fn w8_flags_allocating_codec_calls_on_the_hot_path() {
    let bad = lint_as("train/trainer.rs", include_str!("fixtures/w8_fail.rs"));
    let w8 = hits(&bad, "W8");
    assert_eq!(w8.len(), 3, "{bad:?}");
    assert!(w8[0].msg.contains("pack_signs"), "{:?}", w8[0]);
    assert!(w8.iter().any(|v| v.msg.contains("quantize_diff_into")), "{bad:?}");

    // the same text under outer/ is equally hot-path
    let outer = lint_as("outer/sign_momentum.rs", include_str!("fixtures/w8_fail.rs"));
    assert_eq!(hits(&outer, "W8").len(), 3, "{outer:?}");

    // the exact-lane variants pass, and test-only convenience use is exempt
    let good = lint_as("train/trainer.rs", include_str!("fixtures/w8_pass.rs"));
    assert!(hits(&good, "W8").is_empty(), "{good:?}");

    // scoped: the codec module itself (definitions, round-trip tests)
    // uses the allocating forms freely
    let elsewhere = lint_as("dist/codec.rs", include_str!("fixtures/w8_fail.rs"));
    assert!(hits(&elsewhere, "W8").is_empty(), "{elsewhere:?}");
}

#[test]
fn live_tree_is_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    let violations = match invlint::lint_tree(&root) {
        Ok(v) => v,
        Err(e) => panic!("cannot walk {}: {e}", root.display()),
    };
    assert!(
        violations.is_empty(),
        "invlint found violations in the live tree:\n{}",
        invlint::render(&violations)
    );
}
